//! The simulated data plane a chaos scenario drives.
//!
//! [`SimPool`] is a fluid-model worker pool: broker-side partition queues,
//! an in-flight window (delivered but uncommitted — the at-least-once
//! exposure), and a completed count. Each scheduler tick commits the
//! previous tick's in-flight work and takes up to `workers ×
//! per_worker_per_tick` new messages, split across partitions. A node
//! crash requeues the in-flight window (redelivery, never loss) and
//! removes that node's worker share; the elastic controller — the *real*
//! [`ElasticController`](crate::reactive::elastic::ElasticController), not
//! a model of it — observes `queue_depth` and resizes the pool through
//! [`ScalableTarget`].
//!
//! Messages travel in *cohorts* (a partition, an arrival stamp, a count),
//! so the pool tracks end-to-end latency without per-message allocation:
//! when a cohort commits, `now − arrived` lands in a latency histogram
//! that the scenario's SLO probes read. Capacity is per-partition —
//! workers split `W/P` with the remainder rotating each tick — so a
//! Zipf-hot partition can backlog even while the pool has spare aggregate
//! capacity, exactly the skew failure mode the workload layer provokes.
//! Redelivered cohorts keep their original arrival stamp: a crash shows
//! up in the latency tail, as it would in production.
//!
//! Conservation invariant (checked by every scenario): `offered == queue +
//! in_flight + done` at all times. `redelivered` counts messages that
//! re-entered the queue after a crash — duplicates are allowed, loss is
//! not. With one partition the totals reproduce the original
//! single-queue fluid model tick for tick.

use crate::reactive::elastic::ScalableTarget;
use crate::util::clock::SharedClock;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Timestamped, append-only event log shared by everything in a scenario.
/// Lines are the scenario's observable behaviour — two runs of the same
/// seeded scenario must produce identical traces.
pub struct Trace {
    clock: SharedClock,
    events: Mutex<Vec<String>>,
}

impl Trace {
    pub fn new(clock: SharedClock) -> Arc<Self> {
        Arc::new(Trace { clock, events: Mutex::new(Vec::new()) })
    }

    /// Append one event, stamped with virtual milliseconds.
    pub fn push(&self, event: impl AsRef<str>) {
        let mut ev = self.events.lock().unwrap();
        ev.push(format!("{:>9}ms {}", self.clock.now_millis(), event.as_ref()));
    }

    /// Current virtual time in milliseconds (the clock the stamps use).
    pub fn now_millis(&self) -> u64 {
        self.clock.now_millis()
    }

    pub fn lines(&self) -> Vec<String> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of lines whose event text starts with `prefix`.
    pub fn count_matching(&self, prefix: &str) -> usize {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter(|l| l.split_once("ms ").map(|(_, e)| e.starts_with(prefix)).unwrap_or(false))
            .count()
    }
}

/// A batch of messages that arrived together on one partition.
#[derive(Clone, Copy, Debug)]
struct Cohort {
    arrived_ms: u64,
    count: u64,
}

/// Queues + in-flight windows, guarded together so tick/crash/offer stay
/// atomic with respect to each other.
struct Lanes {
    /// Broker-side queue per partition (FIFO of cohorts).
    queues: Vec<VecDeque<Cohort>>,
    /// Last tick's uncommitted delivery per partition.
    in_flight: Vec<Vec<Cohort>>,
    /// Rotates the capacity remainder across partitions per tick.
    rot: usize,
}

/// Fluid-model elastic worker pool (see module docs).
pub struct SimPool {
    name: String,
    min: usize,
    max: usize,
    /// Messages one worker completes per scheduler tick.
    per_worker_per_tick: u64,
    partitions: usize,
    workers: AtomicUsize,
    lanes: Mutex<Lanes>,
    /// Completed-message latency histogram: latency_ms → message count.
    latency: Mutex<BTreeMap<u64, u64>>,
    // Atomic mirrors of the lane totals, for lock-free reads from monitor
    // threads (`queue_depth` is on the autoscaler's hot path).
    queue: AtomicU64,
    in_flight_total: AtomicU64,
    done: AtomicU64,
    offered: AtomicU64,
    redelivered: AtomicU64,
    peak_workers: AtomicUsize,
    max_outstanding: AtomicU64,
    trace: Arc<Trace>,
}

impl SimPool {
    pub fn new(
        name: &str,
        min: usize,
        max: usize,
        per_worker_per_tick: u64,
        initial_workers: usize,
        partitions: usize,
        trace: Arc<Trace>,
    ) -> Arc<Self> {
        assert!(max >= min.max(1), "SimPool bounds: max {max} < min {min}");
        assert!(per_worker_per_tick > 0);
        assert!(partitions >= 1);
        let initial = initial_workers.clamp(min.max(1), max);
        Arc::new(SimPool {
            name: name.to_string(),
            min,
            max,
            per_worker_per_tick,
            partitions,
            workers: AtomicUsize::new(initial),
            lanes: Mutex::new(Lanes {
                queues: (0..partitions).map(|_| VecDeque::new()).collect(),
                in_flight: (0..partitions).map(|_| Vec::new()).collect(),
                rot: 0,
            }),
            latency: Mutex::new(BTreeMap::new()),
            queue: AtomicU64::new(0),
            in_flight_total: AtomicU64::new(0),
            done: AtomicU64::new(0),
            offered: AtomicU64::new(0),
            redelivered: AtomicU64::new(0),
            peak_workers: AtomicUsize::new(initial),
            max_outstanding: AtomicU64::new(0),
            trace,
        })
    }

    pub fn partitions(&self) -> usize {
        self.partitions
    }

    /// Enqueue `n` new messages on partition 0 (workload arrivals for the
    /// single-partition scenarios).
    pub fn offer(&self, n: u64) {
        self.offer_to(0, n);
    }

    /// Enqueue `n` new messages on one partition, stamped with the
    /// current virtual time.
    pub fn offer_to(&self, partition: usize, n: u64) {
        if n == 0 {
            return;
        }
        assert!(partition < self.partitions, "partition {partition} of {}", self.partitions);
        let arrived_ms = self.trace.now_millis();
        let mut lanes = self.lanes.lock().unwrap();
        let q = &mut lanes.queues[partition];
        // Coalesce with the tail cohort when the stamp matches — arrivals
        // within one tick form one cohort, keeping the queues compact.
        match q.back_mut() {
            Some(tail) if tail.arrived_ms == arrived_ms => tail.count += n,
            _ => q.push_back(Cohort { arrived_ms, count: n }),
        }
        drop(lanes);
        self.offered.fetch_add(n, Ordering::SeqCst);
        self.queue.fetch_add(n, Ordering::SeqCst);
    }

    /// One processing tick: commit last tick's in-flight batches
    /// (recording their end-to-end latency), then take up to capacity
    /// into flight, partition by partition. Driven by the scenario's
    /// scheduler.
    pub fn tick(&self) {
        let now_ms = self.trace.now_millis();
        let mut lanes = self.lanes.lock().unwrap();
        // Commit phase: everything delivered last tick completes now.
        let mut finished = 0u64;
        {
            let mut hist = self.latency.lock().unwrap();
            for lane in lanes.in_flight.iter_mut() {
                for c in lane.drain(..) {
                    finished += c.count;
                    *hist.entry(now_ms.saturating_sub(c.arrived_ms)).or_insert(0) += c.count;
                }
            }
        }
        if finished > 0 {
            self.done.fetch_add(finished, Ordering::SeqCst);
            self.in_flight_total.fetch_sub(finished, Ordering::SeqCst);
        }
        // Delivery phase: split capacity per partition; the remainder
        // rotates so no partition is systematically starved. Unused
        // capacity is *not* reassigned across partitions — a hot
        // partition backlogs even when the pool has aggregate headroom.
        let total_cap = self.workers.load(Ordering::SeqCst) as u64 * self.per_worker_per_tick;
        let p = self.partitions as u64;
        let base = total_cap / p;
        let rem = total_cap % p;
        let rot = lanes.rot;
        lanes.rot = (rot + 1) % self.partitions;
        let mut taken = 0u64;
        for i in 0..self.partitions {
            let extra = u64::from((((i + self.partitions - rot) % self.partitions) as u64) < rem);
            let mut cap = base + extra;
            let (queues, in_flight) = {
                let Lanes { queues, in_flight, .. } = &mut *lanes;
                (&mut queues[i], &mut in_flight[i])
            };
            while cap > 0 {
                match queues.front_mut() {
                    None => break,
                    Some(head) if head.count <= cap => {
                        cap -= head.count;
                        taken += head.count;
                        let c = queues.pop_front().unwrap();
                        in_flight.push(c);
                    }
                    Some(head) => {
                        head.count -= cap;
                        taken += cap;
                        in_flight.push(Cohort { arrived_ms: head.arrived_ms, count: cap });
                        cap = 0;
                    }
                }
            }
        }
        drop(lanes);
        if taken > 0 {
            self.queue.fetch_sub(taken, Ordering::SeqCst);
            self.in_flight_total.fetch_add(taken, Ordering::SeqCst);
        }
        self.max_outstanding.fetch_max(self.outstanding(), Ordering::SeqCst);
    }

    /// Node crash touching this pool: the in-flight window is uncommitted,
    /// so it goes *back to the queue* (redelivery), and the node's worker
    /// share disappears until healed or re-scaled. Requeued cohorts keep
    /// their original arrival stamps and rejoin at the *front* of their
    /// partition — the crash widens the latency tail, it never loses.
    pub fn crash_workers(&self, share: usize) {
        let mut lanes = self.lanes.lock().unwrap();
        let mut lost = 0u64;
        for i in 0..self.partitions {
            let Lanes { queues, in_flight, .. } = &mut *lanes;
            let lane = &mut in_flight[i];
            for c in lane.drain(..).rev() {
                lost += c.count;
                queues[i].push_front(c);
            }
        }
        drop(lanes);
        if lost > 0 {
            self.in_flight_total.fetch_sub(lost, Ordering::SeqCst);
            self.queue.fetch_add(lost, Ordering::SeqCst);
            self.redelivered.fetch_add(lost, Ordering::SeqCst);
            self.trace.push(format!("redeliver {lost} ({})", self.name));
        }
        let _ = self.workers.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |w| {
            Some(w.saturating_sub(share))
        });
    }

    /// Node recovery: restore up to `share` workers (bounded by `max`).
    pub fn heal_workers(&self, share: usize) {
        let _ = self.workers.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |w| {
            Some((w + share).min(self.max))
        });
        self.peak_workers.fetch_max(self.workers.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    pub fn queue(&self) -> u64 {
        self.queue.load(Ordering::SeqCst)
    }

    /// Queued messages on one partition (skew probes read this).
    pub fn partition_queue(&self, partition: usize) -> u64 {
        self.lanes.lock().unwrap().queues[partition].iter().map(|c| c.count).sum()
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight_total.load(Ordering::SeqCst)
    }

    pub fn done(&self) -> u64 {
        self.done.load(Ordering::SeqCst)
    }

    pub fn offered(&self) -> u64 {
        self.offered.load(Ordering::SeqCst)
    }

    pub fn redelivered(&self) -> u64 {
        self.redelivered.load(Ordering::SeqCst)
    }

    /// Messages not yet completed (broker queue + in-flight window).
    pub fn outstanding(&self) -> u64 {
        self.queue.load(Ordering::SeqCst) + self.in_flight_total.load(Ordering::SeqCst)
    }

    pub fn is_drained(&self) -> bool {
        self.outstanding() == 0
    }

    pub fn peak_workers(&self) -> usize {
        self.peak_workers.load(Ordering::SeqCst)
    }

    pub fn max_outstanding(&self) -> u64 {
        self.max_outstanding.load(Ordering::SeqCst)
    }

    /// Fraction of completed messages whose end-to-end latency was at
    /// most `bound_ms`. `1.0` when nothing has completed yet (an empty
    /// run violates no SLO).
    pub fn latency_attainment(&self, bound_ms: u64) -> f64 {
        let hist = self.latency.lock().unwrap();
        let total: u64 = hist.values().sum();
        if total == 0 {
            return 1.0;
        }
        let within: u64 = hist.range(..=bound_ms).map(|(_, n)| n).sum();
        within as f64 / total as f64
    }

    /// Latency quantile in milliseconds over completed messages
    /// (`q` in `[0, 1]`); `None` before anything completes.
    pub fn latency_quantile(&self, q: f64) -> Option<u64> {
        let hist = self.latency.lock().unwrap();
        let total: u64 = hist.values().sum();
        if total == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (ms, n) in hist.iter() {
            seen += n;
            if seen >= rank {
                return Some(*ms);
            }
        }
        hist.keys().next_back().copied()
    }

    /// Conservation residue: nonzero means the model lost or invented
    /// messages — always a bug.
    pub fn conservation_residue(&self) -> i64 {
        self.offered.load(Ordering::SeqCst) as i64
            - (self.outstanding() + self.done.load(Ordering::SeqCst)) as i64
    }
}

impl ScalableTarget for SimPool {
    fn worker_count(&self) -> usize {
        self.workers.load(Ordering::SeqCst)
    }

    fn queue_depth(&self) -> usize {
        self.outstanding() as usize
    }

    fn scale_to(&self, n: usize) {
        let n = n.clamp(self.min.max(1), self.max);
        let before = self.workers.swap(n, Ordering::SeqCst);
        if n != before {
            self.peak_workers.fetch_max(n, Ordering::SeqCst);
            self.trace.push(format!("scale {} {before}->{n}", self.name));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimClock;
    use std::time::Duration;

    fn fixture() -> (Arc<SimClock>, Arc<Trace>, Arc<SimPool>) {
        let clock = Arc::new(SimClock::new());
        let trace = Trace::new(clock.clone());
        let pool = SimPool::new("p", 1, 8, 10, 2, 1, trace.clone());
        (clock, trace, pool)
    }

    #[test]
    fn tick_commits_with_one_tick_lag() {
        let (_c, _t, pool) = fixture();
        pool.offer(25);
        pool.tick(); // takes 20 (2 workers × 10) into flight
        assert_eq!(pool.queue(), 5);
        assert_eq!(pool.in_flight(), 20);
        assert_eq!(pool.done(), 0, "not committed until the next tick");
        pool.tick(); // commits 20, takes remaining 5
        assert_eq!(pool.done(), 20);
        assert_eq!(pool.in_flight(), 5);
        pool.tick();
        assert_eq!(pool.done(), 25);
        assert!(pool.is_drained());
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn crash_redelivers_in_flight_never_loses() {
        let (_c, trace, pool) = fixture();
        pool.offer(100);
        pool.tick(); // 20 in flight
        pool.crash_workers(1);
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(pool.queue(), 100, "in-flight went back to the queue");
        assert_eq!(pool.redelivered(), 20);
        assert_eq!(pool.worker_count(), 1);
        assert_eq!(pool.conservation_residue(), 0);
        assert_eq!(trace.count_matching("redeliver"), 1);
        // Drain the rest: done counts unique completions.
        pool.heal_workers(1);
        for _ in 0..20 {
            pool.tick();
        }
        assert_eq!(pool.done(), 100);
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn scale_to_clamps_and_traces() {
        let (_c, trace, pool) = fixture();
        pool.scale_to(100);
        assert_eq!(pool.worker_count(), 8, "clamped to max");
        pool.scale_to(0);
        assert_eq!(pool.worker_count(), 1, "clamped to min floor");
        pool.scale_to(1); // no change: no trace line
        assert_eq!(trace.count_matching("scale"), 2);
        assert_eq!(pool.peak_workers(), 8);
    }

    #[test]
    fn crash_can_empty_the_pool_heal_restores() {
        let (_c, _t, pool) = fixture();
        pool.crash_workers(5);
        assert_eq!(pool.worker_count(), 0, "crash may drop below the elastic floor");
        pool.heal_workers(3);
        assert_eq!(pool.worker_count(), 3);
        pool.heal_workers(100);
        assert_eq!(pool.worker_count(), 8, "heal bounded by max");
    }

    #[test]
    fn trace_stamps_virtual_time() {
        let (clock, trace, _p) = fixture();
        clock.advance_to(Duration::from_millis(1234));
        trace.push("hello");
        let lines = trace.lines();
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("1234ms hello"), "got: {}", lines[0]);
    }

    #[test]
    fn latency_histogram_tracks_commit_times() {
        let (clock, _t, pool) = fixture();
        pool.offer(25); // arrives at t = 0
        clock.advance_to(Duration::from_millis(500));
        pool.tick(); // 20 into flight
        clock.advance_to(Duration::from_millis(1000));
        pool.tick(); // commits 20 @ 1000 ms latency, takes remaining 5
        clock.advance_to(Duration::from_millis(1500));
        pool.tick(); // commits 5 @ 1500 ms latency
        assert_eq!(pool.done(), 25);
        assert_eq!(pool.latency_quantile(0.5), Some(1000));
        assert_eq!(pool.latency_quantile(1.0), Some(1500));
        let att = pool.latency_attainment(1000);
        assert!((att - 0.8).abs() < 1e-9, "20 of 25 within 1s, got {att}");
        assert_eq!(pool.latency_attainment(1500), 1.0);
        assert_eq!(pool.latency_attainment(10), 0.0);
    }

    #[test]
    fn attainment_is_vacuous_before_any_completion() {
        let (_c, _t, pool) = fixture();
        assert_eq!(pool.latency_attainment(1), 1.0);
        assert_eq!(pool.latency_quantile(0.99), None);
    }

    #[test]
    fn hot_partition_backlogs_despite_aggregate_headroom() {
        let clock = Arc::new(SimClock::new());
        let trace = Trace::new(clock.clone());
        // 4 partitions, 4 workers × 10/tick = 40 total, 10 per partition.
        let pool = SimPool::new("skew", 1, 8, 10, 4, 4, trace);
        for _ in 0..5 {
            pool.offer_to(0, 30); // hot partition: 3× its per-tick share
            pool.offer_to(1, 2);
            pool.tick();
        }
        assert!(
            pool.partition_queue(0) >= 30,
            "hot partition backlog despite idle partitions 2/3: {}",
            pool.partition_queue(0)
        );
        assert_eq!(pool.partition_queue(1), 0, "cold partition keeps up");
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn capacity_remainder_rotates_across_partitions() {
        let clock = Arc::new(SimClock::new());
        let trace = Trace::new(clock.clone());
        // 1 worker × 10/tick over 3 partitions: base 3, remainder 1.
        let pool = SimPool::new("rot", 1, 1, 10, 1, 3, trace);
        for p in 0..3 {
            pool.offer_to(p, 100);
        }
        for _ in 0..6 {
            pool.tick();
        }
        // After 6 ticks each partition got the +1 remainder exactly twice:
        // 6 × 3 base + 2 extra = 20 messages dequeued per partition.
        for p in 0..3 {
            assert_eq!(pool.partition_queue(p), 100 - 20, "partition {p}");
        }
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn crash_preserves_arrival_stamps_for_latency() {
        let (clock, _t, pool) = fixture();
        pool.offer(20); // arrives at t = 0
        clock.advance_to(Duration::from_millis(500));
        pool.tick(); // all 20 in flight
        pool.crash_workers(1); // redelivered, stamp still 0
        pool.heal_workers(1);
        clock.advance_to(Duration::from_millis(1000));
        pool.tick(); // 20 back into flight
        clock.advance_to(Duration::from_millis(1500));
        pool.tick(); // commits with latency 1500, not 500
        assert_eq!(pool.done(), 20);
        assert_eq!(
            pool.latency_quantile(0.5),
            Some(1500),
            "redelivery counts from original arrival"
        );
        assert_eq!(pool.redelivered(), 20);
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn offers_within_one_stamp_coalesce() {
        let (clock, _t, pool) = fixture();
        pool.offer(5);
        pool.offer(5);
        assert_eq!(pool.lanes.lock().unwrap().queues[0].len(), 1, "same-stamp coalesce");
        clock.advance_to(Duration::from_millis(1));
        pool.offer(5);
        assert_eq!(pool.lanes.lock().unwrap().queues[0].len(), 2);
        assert_eq!(pool.queue(), 15);
    }
}
