//! The simulated data plane a chaos scenario drives.
//!
//! [`SimPool`] is a fluid-model worker pool: a broker-side queue, an
//! in-flight window (delivered but uncommitted — the at-least-once
//! exposure), and a completed count. Each scheduler tick commits the
//! previous tick's in-flight work and takes up to `workers ×
//! per_worker_per_tick` new messages. A node crash requeues the in-flight
//! window (redelivery, never loss) and removes that node's worker share;
//! the elastic controller — the *real*
//! [`ElasticController`](crate::reactive::elastic::ElasticController), not
//! a model of it — observes `queue_depth` and resizes the pool through
//! [`ScalableTarget`].
//!
//! Conservation invariant (checked by every scenario): `offered == queue +
//! in_flight + done` at all times. `redelivered` counts messages that
//! re-entered the queue after a crash — duplicates are allowed, loss is
//! not.

use crate::reactive::elastic::ScalableTarget;
use crate::util::clock::SharedClock;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Timestamped, append-only event log shared by everything in a scenario.
/// Lines are the scenario's observable behaviour — two runs of the same
/// seeded scenario must produce identical traces.
pub struct Trace {
    clock: SharedClock,
    events: Mutex<Vec<String>>,
}

impl Trace {
    pub fn new(clock: SharedClock) -> Arc<Self> {
        Arc::new(Trace { clock, events: Mutex::new(Vec::new()) })
    }

    /// Append one event, stamped with virtual milliseconds.
    pub fn push(&self, event: impl AsRef<str>) {
        let mut ev = self.events.lock().unwrap();
        ev.push(format!("{:>9}ms {}", self.clock.now_millis(), event.as_ref()));
    }

    pub fn lines(&self) -> Vec<String> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count of lines whose event text starts with `prefix`.
    pub fn count_matching(&self, prefix: &str) -> usize {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter(|l| l.split_once("ms ").map(|(_, e)| e.starts_with(prefix)).unwrap_or(false))
            .count()
    }
}

/// Fluid-model elastic worker pool (see module docs).
pub struct SimPool {
    name: String,
    min: usize,
    max: usize,
    /// Messages one worker completes per scheduler tick.
    per_worker_per_tick: u64,
    workers: AtomicUsize,
    queue: AtomicU64,
    in_flight: AtomicU64,
    done: AtomicU64,
    offered: AtomicU64,
    redelivered: AtomicU64,
    peak_workers: AtomicUsize,
    max_outstanding: AtomicU64,
    trace: Arc<Trace>,
}

impl SimPool {
    pub fn new(
        name: &str,
        min: usize,
        max: usize,
        per_worker_per_tick: u64,
        initial_workers: usize,
        trace: Arc<Trace>,
    ) -> Arc<Self> {
        assert!(max >= min.max(1), "SimPool bounds: max {max} < min {min}");
        assert!(per_worker_per_tick > 0);
        let initial = initial_workers.clamp(min.max(1), max);
        Arc::new(SimPool {
            name: name.to_string(),
            min,
            max,
            per_worker_per_tick,
            workers: AtomicUsize::new(initial),
            queue: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            done: AtomicU64::new(0),
            offered: AtomicU64::new(0),
            redelivered: AtomicU64::new(0),
            peak_workers: AtomicUsize::new(initial),
            max_outstanding: AtomicU64::new(0),
            trace,
        })
    }

    /// Enqueue `n` new messages (workload arrivals).
    pub fn offer(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.offered.fetch_add(n, Ordering::SeqCst);
        self.queue.fetch_add(n, Ordering::SeqCst);
    }

    /// One processing tick: commit last tick's in-flight batch, then take
    /// up to capacity into flight. Driven by the scenario's scheduler.
    pub fn tick(&self) {
        let finished = self.in_flight.swap(0, Ordering::SeqCst);
        self.done.fetch_add(finished, Ordering::SeqCst);
        let cap = self.workers.load(Ordering::SeqCst) as u64 * self.per_worker_per_tick;
        let take = self.queue.load(Ordering::SeqCst).min(cap);
        if take > 0 {
            self.queue.fetch_sub(take, Ordering::SeqCst);
            self.in_flight.store(take, Ordering::SeqCst);
        }
        self.max_outstanding.fetch_max(self.outstanding(), Ordering::SeqCst);
    }

    /// Node crash touching this pool: the in-flight window is uncommitted,
    /// so it goes *back to the queue* (redelivery), and the node's worker
    /// share disappears until healed or re-scaled.
    pub fn crash_workers(&self, share: usize) {
        let lost = self.in_flight.swap(0, Ordering::SeqCst);
        if lost > 0 {
            self.queue.fetch_add(lost, Ordering::SeqCst);
            self.redelivered.fetch_add(lost, Ordering::SeqCst);
            self.trace.push(format!("redeliver {lost} ({})", self.name));
        }
        let _ = self.workers.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |w| {
            Some(w.saturating_sub(share))
        });
    }

    /// Node recovery: restore up to `share` workers (bounded by `max`).
    pub fn heal_workers(&self, share: usize) {
        let _ = self.workers.fetch_update(Ordering::SeqCst, Ordering::SeqCst, |w| {
            Some((w + share).min(self.max))
        });
        self.peak_workers.fetch_max(self.workers.load(Ordering::SeqCst), Ordering::SeqCst);
    }

    pub fn queue(&self) -> u64 {
        self.queue.load(Ordering::SeqCst)
    }

    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    pub fn done(&self) -> u64 {
        self.done.load(Ordering::SeqCst)
    }

    pub fn offered(&self) -> u64 {
        self.offered.load(Ordering::SeqCst)
    }

    pub fn redelivered(&self) -> u64 {
        self.redelivered.load(Ordering::SeqCst)
    }

    /// Messages not yet completed (broker queue + in-flight window).
    pub fn outstanding(&self) -> u64 {
        self.queue.load(Ordering::SeqCst) + self.in_flight.load(Ordering::SeqCst)
    }

    pub fn is_drained(&self) -> bool {
        self.outstanding() == 0
    }

    pub fn peak_workers(&self) -> usize {
        self.peak_workers.load(Ordering::SeqCst)
    }

    pub fn max_outstanding(&self) -> u64 {
        self.max_outstanding.load(Ordering::SeqCst)
    }

    /// Conservation residue: nonzero means the model lost or invented
    /// messages — always a bug.
    pub fn conservation_residue(&self) -> i64 {
        self.offered.load(Ordering::SeqCst) as i64
            - (self.outstanding() + self.done.load(Ordering::SeqCst)) as i64
    }
}

impl ScalableTarget for SimPool {
    fn worker_count(&self) -> usize {
        self.workers.load(Ordering::SeqCst)
    }

    fn queue_depth(&self) -> usize {
        self.outstanding() as usize
    }

    fn scale_to(&self, n: usize) {
        let n = n.clamp(self.min.max(1), self.max);
        let before = self.workers.swap(n, Ordering::SeqCst);
        if n != before {
            self.peak_workers.fetch_max(n, Ordering::SeqCst);
            self.trace.push(format!("scale {} {before}->{n}", self.name));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimClock;
    use std::time::Duration;

    fn fixture() -> (Arc<SimClock>, Arc<Trace>, Arc<SimPool>) {
        let clock = Arc::new(SimClock::new());
        let trace = Trace::new(clock.clone());
        let pool = SimPool::new("p", 1, 8, 10, 2, trace.clone());
        (clock, trace, pool)
    }

    #[test]
    fn tick_commits_with_one_tick_lag() {
        let (_c, _t, pool) = fixture();
        pool.offer(25);
        pool.tick(); // takes 20 (2 workers × 10) into flight
        assert_eq!(pool.queue(), 5);
        assert_eq!(pool.in_flight(), 20);
        assert_eq!(pool.done(), 0, "not committed until the next tick");
        pool.tick(); // commits 20, takes remaining 5
        assert_eq!(pool.done(), 20);
        assert_eq!(pool.in_flight(), 5);
        pool.tick();
        assert_eq!(pool.done(), 25);
        assert!(pool.is_drained());
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn crash_redelivers_in_flight_never_loses() {
        let (_c, trace, pool) = fixture();
        pool.offer(100);
        pool.tick(); // 20 in flight
        pool.crash_workers(1);
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(pool.queue(), 100, "in-flight went back to the queue");
        assert_eq!(pool.redelivered(), 20);
        assert_eq!(pool.worker_count(), 1);
        assert_eq!(pool.conservation_residue(), 0);
        assert_eq!(trace.count_matching("redeliver"), 1);
        // Drain the rest: done counts unique completions.
        pool.heal_workers(1);
        for _ in 0..20 {
            pool.tick();
        }
        assert_eq!(pool.done(), 100);
        assert_eq!(pool.conservation_residue(), 0);
    }

    #[test]
    fn scale_to_clamps_and_traces() {
        let (_c, trace, pool) = fixture();
        pool.scale_to(100);
        assert_eq!(pool.worker_count(), 8, "clamped to max");
        pool.scale_to(0);
        assert_eq!(pool.worker_count(), 1, "clamped to min floor");
        pool.scale_to(1); // no change: no trace line
        assert_eq!(trace.count_matching("scale"), 2);
        assert_eq!(pool.peak_workers(), 8);
    }

    #[test]
    fn crash_can_empty_the_pool_heal_restores() {
        let (_c, _t, pool) = fixture();
        pool.crash_workers(5);
        assert_eq!(pool.worker_count(), 0, "crash may drop below the elastic floor");
        pool.heal_workers(3);
        assert_eq!(pool.worker_count(), 3);
        pool.heal_workers(100);
        assert_eq!(pool.worker_count(), 8, "heal bounded by max");
    }

    #[test]
    fn trace_stamps_virtual_time() {
        let (clock, trace, _p) = fixture();
        clock.advance_to(Duration::from_millis(1234));
        trace.push("hello");
        let lines = trace.lines();
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("1234ms hello"), "got: {}", lines[0]);
    }
}
