//! Discrete-event scheduler over [`SimClock`] virtual time.
//!
//! Events are closures ordered by `(due, sequence)`: ties at the same
//! virtual instant execute in registration order, so a run is a pure
//! function of the schedule and the seed — two runs with the same seed
//! produce byte-identical event traces. The seeded [`Pcg32`] stream is
//! shared by every stochastic participant (jittered tick periods, the
//! failure injector's dice), which is what makes chaos scenarios
//! reproducible and their interleavings explorable seed-by-seed.

use super::clock::SimClock;
use super::runtime::TickHandle;
use crate::util::clock::SharedClock;
use crate::util::prng::Pcg32;
use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Repeating event state: the callback plus its reschedule rule.
struct EveryState {
    tick: Box<dyn FnMut(&SimScheduler) + Send>,
    period: Duration,
    /// Fractional period jitter in `[0, 1)`; each reschedule perturbs the
    /// period by a factor in `[1 − jitter, 1 + jitter]` drawn from the
    /// scheduler's seeded stream.
    jitter: f64,
    cancelled: Arc<AtomicBool>,
}

enum EventKind {
    Once(Box<dyn FnOnce(&SimScheduler) + Send>),
    Every(EveryState),
}

struct EventEntry {
    due: Duration,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for EventEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}

impl Eq for EventEntry {}

impl PartialOrd for EventEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventEntry {
    /// Reversed so the std max-heap pops the *earliest* `(due, seq)`.
    fn cmp(&self, other: &Self) -> CmpOrdering {
        other.due.cmp(&self.due).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The discrete-event scheduler.
///
/// Single ownership, interior mutability: callbacks receive `&SimScheduler`
/// and may schedule further events re-entrantly (the queue lock is released
/// while a callback runs).
pub struct SimScheduler {
    clock: Arc<SimClock>,
    queue: Mutex<BinaryHeap<EventEntry>>,
    seq: AtomicU64,
    rng: Mutex<Pcg32>,
}

impl SimScheduler {
    pub fn new(seed: u64) -> Self {
        SimScheduler {
            clock: Arc::new(SimClock::new()),
            queue: Mutex::new(BinaryHeap::new()),
            seq: AtomicU64::new(0),
            rng: Mutex::new(Pcg32::new(seed)),
        }
    }

    /// The virtual clock as the stack-wide shared handle.
    pub fn clock(&self) -> SharedClock {
        self.clock.clone()
    }

    /// The virtual clock with its concrete type (tests advance it by hand).
    pub fn sim_clock(&self) -> Arc<SimClock> {
        self.clock.clone()
    }

    /// Current virtual time.
    pub fn now(&self) -> Duration {
        use crate::util::clock::Clock;
        self.clock.now()
    }

    /// Fork an independent RNG stream off the scheduler's seed (for
    /// scenario components that draw their own randomness).
    pub fn fork_rng(&self) -> Pcg32 {
        self.rng.lock().unwrap().fork()
    }

    /// Events currently queued (repeating events count once).
    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    fn push(&self, due: Duration, kind: EventKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        self.queue.lock().unwrap().push(EventEntry { due, seq, kind });
    }

    /// Run `f` once at absolute virtual time `at` (clamped to now).
    pub fn schedule_at(&self, at: Duration, f: impl FnOnce(&SimScheduler) + Send + 'static) {
        let due = at.max(self.now());
        self.push(due, EventKind::Once(Box::new(f)));
    }

    /// Run `f` once after `d` of virtual time.
    pub fn schedule_after(&self, d: Duration, f: impl FnOnce(&SimScheduler) + Send + 'static) {
        self.schedule_at(self.now() + d, f);
    }

    /// Run `f` every `period` of virtual time (first fire one period from
    /// now) until the returned handle is cancelled.
    pub fn schedule_every(
        &self,
        period: Duration,
        f: impl FnMut(&SimScheduler) + Send + 'static,
    ) -> TickHandle {
        self.schedule_every_jittered(period, 0.0, f)
    }

    /// [`SimScheduler::schedule_every`] with a seeded period perturbation:
    /// each interval is `period × [1 − jitter, 1 + jitter]`. Deterministic
    /// per seed; use it to explore timing interleavings reproducibly.
    pub fn schedule_every_jittered(
        &self,
        period: Duration,
        jitter: f64,
        f: impl FnMut(&SimScheduler) + Send + 'static,
    ) -> TickHandle {
        assert!(period > Duration::ZERO, "schedule_every: zero period");
        assert!((0.0..1.0).contains(&jitter), "jitter must be in [0, 1)");
        let cancelled = Arc::new(AtomicBool::new(false));
        self.push(
            self.now() + period,
            EventKind::Every(EveryState {
                tick: Box::new(f),
                period,
                jitter,
                cancelled: cancelled.clone(),
            }),
        );
        TickHandle::detached(cancelled)
    }

    /// Execute every event due up to and including `until`, advancing the
    /// virtual clock event-by-event, then settle the clock at `until`.
    /// Returns the number of callbacks executed.
    pub fn run_until(&self, until: Duration) -> usize {
        let mut executed = 0usize;
        loop {
            let entry = {
                let mut q = self.queue.lock().unwrap();
                match q.peek() {
                    Some(e) if e.due <= until => q.pop(),
                    _ => None,
                }
            };
            let Some(entry) = entry else { break };
            self.clock.advance_to(entry.due);
            match entry.kind {
                EventKind::Once(f) => {
                    executed += 1;
                    f(self);
                }
                EventKind::Every(mut st) => {
                    if st.cancelled.load(Ordering::SeqCst) {
                        continue; // cancelled while queued: drop silently
                    }
                    executed += 1;
                    (st.tick)(self);
                    if st.cancelled.load(Ordering::SeqCst) {
                        continue; // cancelled itself: don't reschedule
                    }
                    let step = if st.jitter > 0.0 {
                        let r = self.rng.lock().unwrap().f64();
                        st.period.mul_f64(1.0 + st.jitter * (2.0 * r - 1.0))
                    } else {
                        st.period
                    };
                    let due = entry.due + step.max(Duration::from_nanos(1));
                    self.push(due, EventKind::Every(st));
                }
            }
        }
        self.clock.advance_to(until);
        executed
    }

    /// Run for `d` more virtual time.
    pub fn run_for(&self, d: Duration) -> usize {
        self.run_until(self.now() + d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recorder() -> Arc<Mutex<Vec<u64>>> {
        Arc::new(Mutex::new(Vec::new()))
    }

    #[test]
    fn events_fire_in_time_order() {
        let s = SimScheduler::new(1);
        let log = recorder();
        let (l1, l2, l3) = (log.clone(), log.clone(), log.clone());
        s.schedule_at(Duration::from_secs(3), move |_| l1.lock().unwrap().push(3));
        s.schedule_at(Duration::from_secs(1), move |_| l2.lock().unwrap().push(1));
        s.schedule_at(Duration::from_secs(2), move |_| l3.lock().unwrap().push(2));
        assert_eq!(s.run_until(Duration::from_secs(10)), 3);
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3]);
        assert_eq!(s.now(), Duration::from_secs(10), "clock settles at the horizon");
    }

    #[test]
    fn same_instant_ties_break_by_registration_order() {
        let s = SimScheduler::new(1);
        let log = recorder();
        for i in 0..5u64 {
            let l = log.clone();
            s.schedule_at(Duration::from_secs(1), move |_| l.lock().unwrap().push(i));
        }
        s.run_until(Duration::from_secs(1));
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn callbacks_schedule_reentrantly() {
        let s = SimScheduler::new(1);
        let log = recorder();
        let l = log.clone();
        s.schedule_at(Duration::from_secs(1), move |sch| {
            l.lock().unwrap().push(1);
            let l2 = l.clone();
            sch.schedule_after(Duration::from_secs(1), move |_| l2.lock().unwrap().push(2));
        });
        s.run_until(Duration::from_secs(5));
        assert_eq!(*log.lock().unwrap(), vec![1, 2]);
    }

    #[test]
    fn every_fires_periodically_until_cancelled() {
        let s = SimScheduler::new(1);
        let log = recorder();
        let l = log.clone();
        let handle = s.schedule_every(Duration::from_secs(1), move |sch| {
            l.lock().unwrap().push(sch.now().as_secs());
        });
        s.run_until(Duration::from_secs(4));
        assert_eq!(*log.lock().unwrap(), vec![1, 2, 3, 4]);
        handle.cancel();
        s.run_until(Duration::from_secs(8));
        assert_eq!(log.lock().unwrap().len(), 4, "no fires after cancel");
    }

    #[test]
    fn run_until_does_not_execute_future_events() {
        let s = SimScheduler::new(1);
        let log = recorder();
        let l = log.clone();
        s.schedule_at(Duration::from_secs(5), move |_| l.lock().unwrap().push(5));
        assert_eq!(s.run_until(Duration::from_secs(4)), 0);
        assert!(log.lock().unwrap().is_empty());
        assert_eq!(s.pending(), 1);
        assert_eq!(s.run_until(Duration::from_secs(5)), 1);
    }

    #[test]
    fn jittered_ticks_are_deterministic_per_seed() {
        let fire_times = |seed: u64| {
            let s = SimScheduler::new(seed);
            let log = Arc::new(Mutex::new(Vec::new()));
            let l = log.clone();
            s.schedule_every_jittered(Duration::from_secs(1), 0.3, move |sch| {
                l.lock().unwrap().push(sch.now().as_millis() as u64);
            });
            s.run_until(Duration::from_secs(60));
            let v = log.lock().unwrap().clone();
            v
        };
        let a = fire_times(42);
        let b = fire_times(42);
        assert_eq!(a, b, "same seed, same virtual fire times");
        assert!(a.len() > 40, "roughly one fire per second, got {}", a.len());
    }

    #[test]
    fn scheduling_in_the_past_clamps_to_now() {
        let s = SimScheduler::new(1);
        s.run_until(Duration::from_secs(10));
        let log = recorder();
        let l = log.clone();
        s.schedule_at(Duration::from_secs(2), move |sch| {
            l.lock().unwrap().push(sch.now().as_secs());
        });
        s.run_until(Duration::from_secs(11));
        assert_eq!(*log.lock().unwrap(), vec![10], "clamped to now, not the past");
    }
}
