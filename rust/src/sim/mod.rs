//! Deterministic virtual-time simulation runtime.
//!
//! The paper's headline claims (Figs. 8–11) are about behaviour *over
//! time*: elastic scale-out under workload spikes, resilient recovery
//! across failure epochs. Exercising them against the real clock costs
//! wall-clock seconds per scenario and is timing-flaky. This module runs
//! the same control plane on **virtual time** instead:
//!
//! - [`clock::SimClock`] — a [`Clock`] that only moves when an event runs;
//! - [`scheduler::SimScheduler`] — a seeded discrete-event scheduler
//!   (`schedule_at` / `schedule_every` / `run_until`) whose event order is
//!   a pure function of the schedule and the seed;
//! - [`runtime`] — the [`Ticker`] seam: the elastic monitor, supervision
//!   sweeper, and failure injector register periodic ticks that run on a
//!   real thread ([`ThreadTicker`]) in production and as discrete events
//!   in simulation;
//! - [`model`] — a fluid-model worker pool ([`SimPool`]) with partitioned
//!   queues, an explicit at-least-once in-flight window, and an
//!   end-to-end latency histogram, driven by the *real*
//!   [`ElasticController`];
//! - [`workload`] — production-shaped load generators: open-loop
//!   Poisson/MMPP arrivals, Zipf key skew onto partitions, diurnal
//!   curves, multi-tenant mixes — all pure functions of the scheduler's
//!   forked RNG;
//! - [`scenario`] — the scenario DSL: workload shapes × models × fault
//!   scripts × assertion probes (including latency SLOs), producing a
//!   byte-comparable [`Trace`];
//! - [`chaos`] — the Fig. 8–11 configurations as a deterministic chaos
//!   matrix plus the policy-race matrix (each elastic policy × each
//!   workload shape; `tests/sim_chaos_matrix.rs` runs both twice and
//!   demands identical traces).
//!
//! The transport layer extends this determinism to *network* faults:
//! [`SimTransport`](crate::transport::SimTransport) schedules its
//! deliveries on [`SimScheduler`], so partition/drop/delay/duplicate/
//! corrupt link scripts replay byte-identically per seed
//! (`tests/transport_sim_chaos.rs`).
//!
//! [`Clock`]: crate::util::clock::Clock
//! [`ElasticController`]: crate::reactive::elastic::ElasticController

pub mod chaos;
pub mod clock;
pub mod executor;
pub mod model;
pub mod runtime;
pub mod scenario;
pub mod scheduler;
pub mod workload;

pub use clock::SimClock;
pub use executor::SimExecutor;
pub use model::{SimPool, Trace};
pub use runtime::{ThreadTicker, TickHandle, Ticker};
pub use scenario::{Fault, LatencySlo, Probes, Scenario, ScenarioReport, WorkloadShape};
pub use scheduler::SimScheduler;
pub use workload::{ArrivalProcess, KeySkew, TenantSpec, WorkloadGen, WorkloadModel, ZipfSampler};
