//! Virtual time source for the discrete-event simulation runtime.
//!
//! A [`SimClock`] is a [`Clock`] whose "now" only moves when the
//! [`SimScheduler`] executes an event (or a test advances it by hand).
//! Components built against [`SharedClock`] — failure detectors, elastic
//! controllers, the failure injector, supervision — run unmodified on
//! virtual time, so minutes of simulated elastic/failure behaviour execute
//! in milliseconds of wall time.
//!
//! [`SimScheduler`]: super::scheduler::SimScheduler
//! [`SharedClock`]: crate::util::clock::SharedClock

use crate::util::clock::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Virtual clock, epoch = 0. Monotone: [`SimClock::advance_to`] never moves
/// time backwards (a stale advance is a no-op), so event callbacks can
/// advance freely without ordering hazards.
pub struct SimClock {
    nanos: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock { nanos: AtomicU64::new(0) }
    }

    /// Move virtual time forward to `t` (no-op if `t` is in the past).
    pub fn advance_to(&self, t: Duration) {
        self.nanos.fetch_max(t.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Move virtual time forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SharedClock;
    use std::sync::Arc;

    #[test]
    fn starts_at_zero_and_advances() {
        let c = SimClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now_millis(), 250);
        c.advance_to(Duration::from_secs(2));
        assert_eq!(c.now(), Duration::from_secs(2));
    }

    #[test]
    fn advance_to_is_monotone() {
        let c = SimClock::new();
        c.advance_to(Duration::from_secs(5));
        c.advance_to(Duration::from_secs(3)); // stale: ignored
        assert_eq!(c.now(), Duration::from_secs(5));
    }

    #[test]
    fn usable_as_shared_clock() {
        let c: SharedClock = Arc::new(SimClock::new());
        assert_eq!(c.now_millis(), 0);
    }
}
