//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `binary <subcommand> [--key value]... [--flag]...` plus free
//! positional arguments. Unknown keys are kept and can be rejected by the
//! caller via [`Args::finish`].

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' unsupported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.options.get(name).cloned()
    }

    pub fn opt_parse<T: std::str::FromStr>(&mut self, name: &str) -> Result<Option<T>, String> {
        match self.opt_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{s}'")),
        }
    }

    pub fn opt_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> Result<T, String> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// Error on any option/flag the caller never consumed.
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown arguments: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_options_flags() {
        let mut a = parse(&["run", "--seed", "7", "--fast", "--out=x.csv", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt_or("seed", 0u64).unwrap(), 7);
        assert!(a.flag("fast"));
        assert_eq!(a.opt_str("out").as_deref(), Some("x.csv"));
        assert_eq!(a.positional, vec!["extra"]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn unknown_args_rejected() {
        let mut a = parse(&["run", "--mystery", "1"]);
        let _ = a.flag("known");
        assert!(a.finish().is_err());
    }

    #[test]
    fn parse_errors() {
        let mut a = parse(&["run", "--n", "abc"]);
        assert!(a.opt_parse::<u32>("n").is_err());
    }

    #[test]
    fn flag_before_end() {
        let mut a = parse(&["bench", "--quick", "--n", "5"]);
        assert!(a.flag("quick"));
        assert_eq!(a.opt_or("n", 0u32).unwrap(), 5);
    }

    #[test]
    fn defaults_when_missing() {
        let mut a = parse(&["x"]);
        assert_eq!(a.opt_or("n", 9u32).unwrap(), 9);
        assert!(!a.flag("v"));
    }
}
