//! TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported grammar — enough for experiment configs:
//! `[section]` headers, `key = value` pairs where value is a quoted string,
//! integer, float, or bool; `#` comments; blank lines. Keys before any
//! section header land in the `""` section.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// A parsed document: `(section, key) → value`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: BTreeMap<(String, String), Value>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key)? {
            Value::Str(s) => Some(s.clone()),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`time_scale = 2`).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(format!("line {line_no}: empty value"));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("line {line_no}: unterminated string"))?;
        if inner.contains('"') {
            return Err(format!("line {line_no}: embedded quote unsupported"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("line {line_no}: cannot parse value '{raw}'"))
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (i, line) in text.lines().enumerate() {
        let line_no = i + 1;
        // Strip comments (not inside strings — our strings ban '#'-after-'"'
        // edge cases by splitting on '#' only outside quotes).
        let mut in_str = false;
        let mut cut = line.len();
        for (bi, ch) in line.char_indices() {
            match ch {
                '"' => in_str = !in_str,
                '#' if !in_str => {
                    cut = bi;
                    break;
                }
                _ => {}
            }
        }
        let line = line[..cut].trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| format!("line {line_no}: malformed section header"))?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {line_no}: expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {line_no}: empty key"));
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        doc.entries.insert((section.clone(), key.to_string()), value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            "top = 1\n[a]\nname = \"x\" # trailing comment\nn = 42\nf = 2.5\nflag = true\n\n[b]\nn = -7\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("", "top"), Some(1));
        assert_eq!(doc.get_str("a", "name"), Some("x".into()));
        assert_eq!(doc.get_int("a", "n"), Some(42));
        assert_eq!(doc.get_float("a", "f"), Some(2.5));
        assert_eq!(doc.get_bool("a", "flag"), Some(true));
        assert_eq!(doc.get_int("b", "n"), Some(-7));
        assert_eq!(doc.len(), 6);
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("[s]\nx = 3\n").unwrap();
        assert_eq!(doc.get_float("s", "x"), Some(3.0));
    }

    #[test]
    fn type_mismatch_is_none() {
        let doc = parse("[s]\nx = \"str\"\n").unwrap();
        assert_eq!(doc.get_int("s", "x"), None);
        assert_eq!(doc.get_str("s", "x"), Some("str".into()));
    }

    #[test]
    fn errors_carry_line_numbers() {
        assert!(parse("[unclosed\n").unwrap_err().contains("line 1"));
        assert!(parse("[a]\nnoequals\n").unwrap_err().contains("line 2"));
        assert!(parse("[a]\nk = \"open\n").unwrap_err().contains("line 2"));
        assert!(parse("[a]\nk = what\n").unwrap_err().contains("line 2"));
    }

    #[test]
    fn comment_with_hash_in_string() {
        let doc = parse("[s]\npath = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s", "path"), Some("a#b".into()));
    }
}
