//! Typed configuration for the whole stack.
//!
//! [`ExperimentConfig`] mirrors the paper's experimental setup (§4.3):
//! architecture variant (Liquid with a fixed task count vs. Reactive
//! Liquid), partitions per topic, cluster size, failure probability per
//! epoch, restart delay, and the consume batch size `n` of Equations 1–2.
//! Wall-clock quantities are expressed in *paper minutes* and compressed by
//! [`ExperimentConfig::time_scale`] (default: one paper minute → one
//! second) so full experiment grids run in CI-scale time.
//!
//! Configs load from a TOML-subset file ([`toml`]), can be overridden from
//! CLI flags ([`cli`]), and carry an explicit RNG seed for reproducibility.

pub mod cli;
pub mod toml;

use std::time::Duration;

/// Which architecture a run exercises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Architecture {
    /// The Liquid baseline: each task *is* a consumer-group member, so at
    /// most `partitions` tasks do useful work; `tasks_per_job` is fixed.
    Liquid { tasks_per_job: usize },
    /// Reactive Liquid: virtual messaging layer + elastic task pools.
    Reactive,
}

impl Architecture {
    pub fn label(&self) -> String {
        match self {
            Architecture::Liquid { tasks_per_job } => format!("liquid-{tasks_per_job}"),
            Architecture::Reactive => "reactive".to_string(),
        }
    }
}

/// How the VML distributes messages to tasks (§5 names the scheduler as
/// future work; `CompletionTime` implements it).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    /// Join-the-shortest-queue on current mailbox depth.
    ShortestQueue,
    /// Least outstanding *work*: queue depth weighted by the task's
    /// observed mean processing time — the completion-time-aware scheduler
    /// the paper's conclusion calls for.
    CompletionTime,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "shortest-queue" | "jsq" => Some(RouterPolicy::ShortestQueue),
            "completion-time" | "ct" => Some(RouterPolicy::CompletionTime),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::ShortestQueue => "shortest-queue",
            RouterPolicy::CompletionTime => "completion-time",
        }
    }
}

/// TCMM nearest-search backend for the micro-clustering hot loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TcmmBackend {
    /// Pure-rust scalar implementation.
    Cpu,
    /// AOT-compiled JAX/Pallas kernel via PJRT (falls back to CPU when
    /// artifacts are absent).
    Xla,
}

/// Which scaling-decision rule the elastic controller runs (the taxonomy
/// of de Assunção et al.: threshold, PID-style, predictive). The policy
/// implementations live in `reactive::elastic`; this enum is just the
/// config-level name so TOML files and CLI flags can pick one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Watermark rule: proportional scale-out past the high watermark,
    /// one-step scale-in under the low one (the original behaviour).
    Threshold,
    /// PID controller on the "workers needed" error with anti-windup.
    Pid,
    /// Extrapolates the queue-growth derivative and provisions ahead.
    Predictive,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "threshold" => Some(PolicyKind::Threshold),
            "pid" => Some(PolicyKind::Pid),
            "predictive" => Some(PolicyKind::Predictive),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Threshold => "threshold",
            PolicyKind::Pid => "pid",
            PolicyKind::Predictive => "predictive",
        }
    }

    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::Threshold, PolicyKind::Pid, PolicyKind::Predictive];
}

/// Elastic-worker service tuning (reactive processing layer).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ElasticConfig {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Scale *out* when mean mailbox depth per worker exceeds this.
    pub high_watermark: usize,
    /// Scale *in* when it drops below this.
    pub low_watermark: usize,
    /// How often the autoscaler evaluates (real time).
    pub check_interval: Duration,
    /// Minimum time between scaling actions.
    pub cooldown: Duration,
    /// Which decision rule drives scaling.
    pub policy: PolicyKind,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            min_workers: 1,
            max_workers: 16,
            high_watermark: 64,
            low_watermark: 8,
            check_interval: Duration::from_millis(100),
            cooldown: Duration::from_millis(300),
            policy: PolicyKind::Threshold,
        }
    }
}

/// Broker durability: where (and whether) the messaging layer persists
/// partitions and committed offsets, and how aggressively it fsyncs.
/// `rl-node broker` exposes the same pair as `--data-dir` / `--fsync`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DurabilityConfig {
    /// Data directory for the on-disk segment log; `None` = in-memory
    /// broker (the simulation default — chaos runs stay deterministic).
    pub data_dir: Option<String>,
    /// When appends/checkpoints are fdatasync'd past the OS cache.
    pub fsync: crate::messaging::storage::FsyncPolicy,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        DurabilityConfig { data_dir: None, fsync: crate::messaging::storage::FsyncPolicy::PerBatch }
    }
}

/// Synthetic T-Drive workload parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkloadConfig {
    pub taxis: usize,
    /// GPS points generated per taxi.
    pub points_per_taxi: usize,
    /// Ingest rate into the messaging layer (points/sec); 0 = as fast as
    /// possible.
    pub ingest_rate: u64,
    /// Spatial cluster hot-spots the taxis orbit (drives TCMM structure).
    pub hotspots: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { taxis: 200, points_per_taxi: 100, ingest_rate: 0, hotspots: 8 }
    }
}

/// Full experiment description (one run of one architecture).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub arch: Architecture,
    /// Partitions per topic (paper: 3).
    pub partitions: usize,
    /// Compute nodes in the simulated cluster (paper: 3).
    pub nodes: usize,
    /// Run length in *paper minutes*.
    pub duration_paper_min: f64,
    /// Node failure probability per epoch (paper: 0/0.3/0.6/0.9).
    pub failure_prob: f64,
    /// Failure-epoch length in paper minutes (paper: 10).
    pub failure_epoch_paper_min: f64,
    /// Restart delay in paper minutes (paper: 5).
    pub restart_paper_min: f64,
    /// Seconds of real time per paper minute (default 1.0).
    pub time_scale: f64,
    /// Consume batch size `n` in Equations 1–2.
    pub consume_batch: usize,
    pub seed: u64,
    pub elastic: ElasticConfig,
    pub workload: WorkloadConfig,
    pub backend: TcmmBackend,
    pub router: RouterPolicy,
    /// Micro-clustering distance threshold (degrees-ish units). Small
    /// enough that hotspots splinter into many micro-clusters — the set
    /// grows over the run, decelerating micro-clustering exactly as the
    /// paper observes ("the micro-clusters size grows over time and
    /// decelerates the micro-clustering").
    pub tcmm_threshold: f32,
    /// Macro-clustering period in paper minutes.
    pub macro_period_paper_min: f64,
    /// Per-task speed heterogeneity: task speed factors spread over
    /// `[1, 1+spread]` (0 = homogeneous). Models heterogeneous nodes; the
    /// §5 scheduler ablation uses it (a distribution scheduler only
    /// matters when tasks differ).
    pub task_speed_spread: f64,
    pub durability: DurabilityConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            arch: Architecture::Reactive,
            partitions: 3,
            nodes: 3,
            duration_paper_min: 30.0,
            failure_prob: 0.0,
            failure_epoch_paper_min: 10.0,
            restart_paper_min: 5.0,
            time_scale: 1.0,
            consume_batch: 32,
            seed: 42,
            elastic: ElasticConfig::default(),
            workload: WorkloadConfig::default(),
            backend: TcmmBackend::Cpu,
            router: RouterPolicy::RoundRobin,
            tcmm_threshold: 0.003,
            macro_period_paper_min: 5.0,
            task_speed_spread: 0.0,
            durability: DurabilityConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Convert paper minutes to scaled wall-clock duration.
    pub fn scaled(&self, paper_min: f64) -> Duration {
        Duration::from_secs_f64(paper_min * self.time_scale)
    }

    pub fn duration(&self) -> Duration {
        self.scaled(self.duration_paper_min)
    }

    pub fn failure_epoch(&self) -> Duration {
        self.scaled(self.failure_epoch_paper_min)
    }

    pub fn restart_delay(&self) -> Duration {
        self.scaled(self.restart_paper_min)
    }

    pub fn macro_period(&self) -> Duration {
        self.scaled(self.macro_period_paper_min)
    }

    /// Sanity-check invariants; call after assembling from file/CLI.
    pub fn validate(&self) -> Result<(), String> {
        if self.partitions == 0 {
            return Err("partitions must be >= 1".into());
        }
        if self.nodes == 0 {
            return Err("nodes must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.failure_prob) {
            return Err(format!("failure_prob {} outside [0,1]", self.failure_prob));
        }
        if self.consume_batch == 0 {
            return Err("consume_batch must be >= 1".into());
        }
        if let Architecture::Liquid { tasks_per_job } = self.arch {
            if tasks_per_job == 0 {
                return Err("liquid tasks_per_job must be >= 1".into());
            }
        }
        if self.elastic.min_workers == 0 || self.elastic.min_workers > self.elastic.max_workers {
            return Err("elastic worker bounds invalid".into());
        }
        if self.time_scale <= 0.0 {
            return Err("time_scale must be > 0".into());
        }
        Ok(())
    }

    /// Load from a TOML-subset file, falling back to defaults per key.
    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let doc = toml::parse(&text)?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Overlay parsed TOML keys onto this config.
    pub fn apply(&mut self, doc: &toml::Doc) -> Result<(), String> {
        if let Some(a) = doc.get_str("experiment", "arch") {
            self.arch = match a.as_str() {
                "reactive" => Architecture::Reactive,
                "liquid" => Architecture::Liquid {
                    tasks_per_job: doc.get_int("experiment", "tasks_per_job").unwrap_or(3) as usize,
                },
                other => return Err(format!("unknown arch '{other}'")),
            };
        }
        if let Some(v) = doc.get_int("experiment", "partitions") {
            self.partitions = v as usize;
        }
        if let Some(v) = doc.get_int("experiment", "nodes") {
            self.nodes = v as usize;
        }
        if let Some(v) = doc.get_float("experiment", "duration_paper_min") {
            self.duration_paper_min = v;
        }
        if let Some(v) = doc.get_float("experiment", "failure_prob") {
            self.failure_prob = v;
        }
        if let Some(v) = doc.get_float("experiment", "failure_epoch_paper_min") {
            self.failure_epoch_paper_min = v;
        }
        if let Some(v) = doc.get_float("experiment", "restart_paper_min") {
            self.restart_paper_min = v;
        }
        if let Some(v) = doc.get_float("experiment", "time_scale") {
            self.time_scale = v;
        }
        if let Some(v) = doc.get_int("experiment", "consume_batch") {
            self.consume_batch = v as usize;
        }
        if let Some(v) = doc.get_int("experiment", "seed") {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get_str("experiment", "backend") {
            self.backend = match v.as_str() {
                "cpu" => TcmmBackend::Cpu,
                "xla" => TcmmBackend::Xla,
                other => return Err(format!("unknown backend '{other}'")),
            };
        }
        if let Some(v) = doc.get_str("experiment", "router") {
            self.router =
                RouterPolicy::parse(&v).ok_or_else(|| format!("unknown router '{v}'"))?;
        }
        if let Some(v) = doc.get_int("elastic", "min_workers") {
            self.elastic.min_workers = v as usize;
        }
        if let Some(v) = doc.get_int("elastic", "max_workers") {
            self.elastic.max_workers = v as usize;
        }
        if let Some(v) = doc.get_int("elastic", "high_watermark") {
            self.elastic.high_watermark = v as usize;
        }
        if let Some(v) = doc.get_int("elastic", "low_watermark") {
            self.elastic.low_watermark = v as usize;
        }
        if let Some(v) = doc.get_str("elastic", "policy") {
            self.elastic.policy =
                PolicyKind::parse(&v).ok_or_else(|| format!("unknown elastic policy '{v}'"))?;
        }
        if let Some(v) = doc.get_int("workload", "taxis") {
            self.workload.taxis = v as usize;
        }
        if let Some(v) = doc.get_int("workload", "points_per_taxi") {
            self.workload.points_per_taxi = v as usize;
        }
        if let Some(v) = doc.get_int("workload", "ingest_rate") {
            self.workload.ingest_rate = v as u64;
        }
        if let Some(v) = doc.get_int("workload", "hotspots") {
            self.workload.hotspots = v as usize;
        }
        if let Some(v) = doc.get_float("tcmm", "threshold") {
            self.tcmm_threshold = v as f32;
        }
        if let Some(v) = doc.get_float("tcmm", "macro_period_paper_min") {
            self.macro_period_paper_min = v;
        }
        if let Some(v) = doc.get_str("durability", "data_dir") {
            self.durability.data_dir = Some(v);
        }
        if let Some(v) = doc.get_str("durability", "fsync") {
            self.durability.fsync = crate::messaging::storage::FsyncPolicy::parse(&v)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn scaled_durations() {
        let mut c = ExperimentConfig::default();
        c.time_scale = 2.0;
        assert_eq!(c.failure_epoch(), Duration::from_secs(20));
        assert_eq!(c.restart_delay(), Duration::from_secs(10));
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.failure_prob = 1.5;
        assert!(c.validate().is_err());
        c.failure_prob = 0.3;
        c.partitions = 0;
        assert!(c.validate().is_err());
        c.partitions = 3;
        c.arch = Architecture::Liquid { tasks_per_job: 0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn apply_from_toml() {
        let doc = toml::parse(
            "[experiment]\narch = \"liquid\"\ntasks_per_job = 6\npartitions = 4\n\
             failure_prob = 0.6\nrouter = \"jsq\"\n[workload]\ntaxis = 10\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.arch, Architecture::Liquid { tasks_per_job: 6 });
        assert_eq!(c.partitions, 4);
        assert_eq!(c.failure_prob, 0.6);
        assert_eq!(c.router, RouterPolicy::ShortestQueue);
        assert_eq!(c.workload.taxis, 10);
    }

    #[test]
    fn arch_labels() {
        assert_eq!(Architecture::Liquid { tasks_per_job: 3 }.label(), "liquid-3");
        assert_eq!(Architecture::Reactive.label(), "reactive");
    }

    #[test]
    fn elastic_policy_from_toml() {
        assert_eq!(ExperimentConfig::default().elastic.policy, PolicyKind::Threshold);
        let doc = toml::parse("[elastic]\npolicy = \"pid\"\n").unwrap();
        let mut c = ExperimentConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.elastic.policy, PolicyKind::Pid);
        let bad = toml::parse("[elastic]\npolicy = \"vibes\"\n").unwrap();
        assert!(ExperimentConfig::default().apply(&bad).is_err());
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(kind.label()), Some(kind));
        }
    }

    #[test]
    fn durability_from_toml() {
        use crate::messaging::storage::FsyncPolicy;
        assert_eq!(ExperimentConfig::default().durability.data_dir, None);
        let doc = toml::parse(
            "[durability]\ndata_dir = \"/tmp/rl-data\"\nfsync = \"interval:25\"\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.durability.data_dir.as_deref(), Some("/tmp/rl-data"));
        assert_eq!(c.durability.fsync, FsyncPolicy::IntervalMs(25));
        let bad = toml::parse("[durability]\nfsync = \"sometimes\"\n").unwrap();
        assert!(ExperimentConfig::default().apply(&bad).is_err());
    }
}
