//! `reactive-liquid` CLI — the launcher.
//!
//! Subcommands:
//!
//! - `run [--config FILE] [--arch reactive|liquid] [...]` — run one
//!   experiment and print the §4.3 metrics;
//! - `figure <8|9|10|11|router>` — regenerate a paper figure's data;
//! - `gen-data --out FILE [--taxis N] [--points N]` — write a synthetic
//!   T-Drive-format dataset;
//! - `info` — environment/report (artifacts, cores).

use reactive_liquid::config::cli::Args;
use reactive_liquid::config::{Architecture, ExperimentConfig, PolicyKind, RouterPolicy, TcmmBackend};
use reactive_liquid::experiment::figures::{self, FigureOpts};
use reactive_liquid::experiment::run_experiment;
use reactive_liquid::runtime::artifacts_dir;
use reactive_liquid::trajectory::TrajectoryGenerator;
use std::io::Write;

fn main() {
    let args = Args::from_env().unwrap_or_else(|e| {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    });
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let code = match sub.as_str() {
        "run" => cmd_run(args),
        "figure" => cmd_figure(args),
        "gen-data" => cmd_gen_data(args),
        "info" => cmd_info(),
        _ => {
            print!(
                "reactive-liquid — elastic & resilient distributed data processing\n\n\
                 usage: reactive-liquid <run|figure|gen-data|info> [options]\n\n\
                 run       --config FILE | --arch reactive|liquid --tasks N --secs S\n\
                 \x20         --failure-prob P --rate R --router rr|jsq|ct --backend cpu|xla\n\
                 \x20         --policy threshold|pid|predictive\n\
                 figure    8 | 9 | 10 | 11 | router   (writes results/*.csv)\n\
                 gen-data  --out FILE --taxis N --points N --seed S\n\
                 info      print environment report\n"
            );
            0
        }
    };
    std::process::exit(code);
}

fn cmd_run(mut args: Args) -> i32 {
    let mut cfg = match args.opt_str("config") {
        Some(path) => match ExperimentConfig::from_file(&path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        },
        None => ExperimentConfig::default(),
    };
    if let Some(arch) = args.opt_str("arch") {
        cfg.arch = match arch.as_str() {
            "reactive" => Architecture::Reactive,
            "liquid" => Architecture::Liquid {
                tasks_per_job: args.opt_or("tasks", 3).unwrap_or(3),
            },
            other => {
                eprintln!("unknown --arch '{other}'");
                return 2;
            }
        };
    }
    if let Ok(Some(secs)) = args.opt_parse::<f64>("secs") {
        cfg.duration_paper_min = secs;
    }
    if let Ok(Some(p)) = args.opt_parse::<f64>("failure-prob") {
        cfg.failure_prob = p;
    }
    if let Ok(Some(r)) = args.opt_parse::<u64>("rate") {
        cfg.workload.ingest_rate = r;
    }
    if let Some(r) = args.opt_str("router") {
        match RouterPolicy::parse(&r) {
            Some(p) => cfg.router = p,
            None => {
                eprintln!("unknown --router '{r}'");
                return 2;
            }
        }
    }
    if let Some(p) = args.opt_str("policy") {
        match PolicyKind::parse(&p) {
            Some(k) => cfg.elastic.policy = k,
            None => {
                eprintln!("unknown --policy '{p}'");
                return 2;
            }
        }
    }
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = if b == "xla" { TcmmBackend::Xla } else { TcmmBackend::Cpu };
    }
    if let Ok(Some(s)) = args.opt_parse::<u64>("seed") {
        cfg.seed = s;
    }
    let _ = args.flag("quiet");
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }
    if let Err(e) = cfg.validate() {
        eprintln!("invalid config: {e}");
        return 2;
    }
    let r = run_experiment(&cfg);
    println!("{}", r.summary());
    println!("{}", r.to_json().render());
    0
}

fn cmd_figure(args: Args) -> i32 {
    let which = args.positional.first().cloned().unwrap_or_default();
    let opts = FigureOpts::default();
    std::fs::create_dir_all(&opts.out_dir).ok();
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }
    match which.as_str() {
        "8" => {
            figures::fig8(&opts);
        }
        "9" => {
            let l3 = run_experiment(&opts.cfg(Architecture::Liquid { tasks_per_job: 3 }));
            let rl = run_experiment(&opts.cfg(Architecture::Reactive));
            let fit = figures::fig9_pair(&l3, &rl, &opts.out_dir.join("fig9a.csv")).unwrap();
            println!("fig9a fit: slope={:.3} R²={:.3}", fit.slope, fit.r_squared);
        }
        "10" => {
            figures::fig10(&opts);
        }
        "11" => {
            figures::fig11(&opts);
        }
        "router" => {
            figures::ablation_router(&opts);
        }
        other => {
            eprintln!("unknown figure '{other}' (expected 8|9|10|11|router)");
            return 2;
        }
    }
    0
}

fn cmd_gen_data(mut args: Args) -> i32 {
    let out = args.opt_str("out").unwrap_or_else(|| "tdrive_synth.txt".to_string());
    let taxis: usize = args.opt_or("taxis", 100).unwrap_or(100);
    let points: usize = args.opt_or("points", 100).unwrap_or(100);
    let seed: u64 = args.opt_or("seed", 42).unwrap_or(42);
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }
    let mut gen = TrajectoryGenerator::new(taxis, 8, seed);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out).expect("create out"));
    // T-Drive text format, timestamps inside the dataset's week.
    let base = 1_201_910_400u64; // 2008-02-02 00:00:00
    for p in gen.generate(points) {
        let ts = base + p.ts;
        let days_into_week = ((ts - base) / 86_400).min(6) as u32;
        let rem = ts % 86_400;
        writeln!(
            f,
            "{},2008-02-{:02} {:02}:{:02}:{:02},{:.5},{:.5}",
            p.taxi_id,
            2 + days_into_week,
            rem / 3600,
            (rem % 3600) / 60,
            rem % 60,
            p.lon,
            p.lat
        )
        .unwrap();
    }
    println!("wrote {} points for {taxis} taxis to {out}", taxis * points);
    0
}

fn cmd_info() -> i32 {
    println!("reactive-liquid {}", env!("CARGO_PKG_VERSION"));
    println!("cores: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
    match artifacts_dir() {
        Some(d) => println!("artifacts: {}", d.display()),
        None => println!("artifacts: NOT FOUND (run `make artifacts`)"),
    }
    0
}
