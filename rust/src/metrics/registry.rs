//! Named atomic counters and gauges.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::RwLock;

/// Concurrent registry of named counters (monotonic) and gauges (signed,
/// set/add). Lookup takes a read lock; the counter bump itself is a single
/// atomic add, so hot paths should cache the `&AtomicU64` via [`counter`].
///
/// [`counter`]: MetricsRegistry::counter
pub struct MetricsRegistry {
    counters: RwLock<HashMap<String, &'static AtomicU64>>,
    gauges: RwLock<HashMap<String, &'static AtomicI64>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry { counters: RwLock::new(HashMap::new()), gauges: RwLock::new(HashMap::new()) }
    }

    /// Get (or create) a counter handle. The handle is `'static` (leaked
    /// once per name) so hot loops can bump it without any lock.
    pub fn counter(&self, name: &str) -> &'static AtomicU64 {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return c;
        }
        let mut w = self.counters.write().unwrap();
        w.entry(name.to_string()).or_insert_with(|| Box::leak(Box::new(AtomicU64::new(0))))
    }

    pub fn gauge(&self, name: &str) -> &'static AtomicI64 {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            return g;
        }
        let mut w = self.gauges.write().unwrap();
        w.entry(name.to_string()).or_insert_with(|| Box::leak(Box::new(AtomicI64::new(0))))
    }

    pub fn inc(&self, name: &str) {
        self.counter(name).fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    pub fn get_gauge(&self, name: &str) -> i64 {
        self.gauge(name).load(Ordering::Relaxed)
    }

    /// Snapshot all counters (sorted by name, for reports).
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.load(Ordering::Relaxed)))
            .collect();
        v.sort();
        v
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate() {
        let r = MetricsRegistry::new();
        r.inc("a");
        r.add("a", 4);
        assert_eq!(r.get("a"), 5);
        assert_eq!(r.get("missing"), 0);
    }

    #[test]
    fn gauges_set() {
        let r = MetricsRegistry::new();
        r.set_gauge("workers", 7);
        assert_eq!(r.get_gauge("workers"), 7);
        r.set_gauge("workers", 3);
        assert_eq!(r.get_gauge("workers"), 3);
    }

    #[test]
    fn concurrent_increments_all_land() {
        let r = Arc::new(MetricsRegistry::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                let c = r.counter("hot");
                for _ in 0..10_000 {
                    c.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.get("hot"), 80_000);
    }

    #[test]
    fn snapshot_sorted() {
        let r = MetricsRegistry::new();
        r.inc("zeta");
        r.inc("alpha");
        let s = r.snapshot();
        assert_eq!(s[0].0, "alpha");
        assert_eq!(s[1].0, "zeta");
    }
}
