//! Per-second event counting for the throughput / total-processed figures.

use crate::util::clock::SharedClock;
use std::sync::Mutex;

/// Counts events into one-second buckets keyed by the shared clock.
///
/// Figures 8 and 10 plot the cumulative series; Figure 9 pairs the
/// per-second (throughput) series of two runs.
pub struct TimeSeries {
    clock: SharedClock,
    buckets: Mutex<Vec<u64>>,
}

impl TimeSeries {
    pub fn new(clock: SharedClock) -> Self {
        TimeSeries { clock, buckets: Mutex::new(Vec::new()) }
    }

    /// Record `n` events at the current clock second.
    pub fn record(&self, n: u64) {
        let sec = self.clock.now().as_secs() as usize;
        let mut b = self.buckets.lock().unwrap();
        if b.len() <= sec {
            b.resize(sec + 1, 0);
        }
        b[sec] += n;
    }

    /// Total events recorded.
    pub fn total(&self) -> u64 {
        self.buckets.lock().unwrap().iter().sum()
    }

    /// `(second, events_in_that_second)` — the throughput series.
    pub fn rate_series(&self) -> Vec<(u64, u64)> {
        self.buckets.lock().unwrap().iter().enumerate().map(|(i, &c)| (i as u64, c)).collect()
    }

    /// `(second, cumulative_events)` — the total-processed series.
    pub fn cumulative_series(&self) -> Vec<(u64, u64)> {
        let b = self.buckets.lock().unwrap();
        let mut acc = 0u64;
        b.iter()
            .enumerate()
            .map(|(i, &c)| {
                acc += c;
                (i as u64, acc)
            })
            .collect()
    }

    /// Throughput series padded/truncated to exactly `secs` entries, as f64
    /// (what Figure 9 pairs across runs).
    pub fn rate_series_f64(&self, secs: usize) -> Vec<f64> {
        let b = self.buckets.lock().unwrap();
        (0..secs).map(|i| b.get(i).copied().unwrap_or(0) as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::sync::Arc;
    use std::time::Duration;

    fn fixture() -> (Arc<ManualClock>, TimeSeries) {
        let clock = Arc::new(ManualClock::new());
        let ts = TimeSeries::new(clock.clone());
        (clock, ts)
    }

    #[test]
    fn buckets_by_second() {
        let (clock, ts) = fixture();
        ts.record(2);
        clock.advance(Duration::from_millis(999));
        ts.record(1); // still second 0
        clock.advance(Duration::from_millis(2));
        ts.record(5); // second 1
        clock.advance(Duration::from_secs(2));
        ts.record(1); // second 3
        assert_eq!(ts.rate_series(), vec![(0, 3), (1, 5), (2, 0), (3, 1)]);
        assert_eq!(ts.cumulative_series(), vec![(0, 3), (1, 8), (2, 8), (3, 9)]);
        assert_eq!(ts.total(), 9);
    }

    #[test]
    fn rate_series_f64_pads_and_truncates() {
        let (clock, ts) = fixture();
        ts.record(4);
        clock.advance(Duration::from_secs(1));
        ts.record(6);
        assert_eq!(ts.rate_series_f64(4), vec![4.0, 6.0, 0.0, 0.0]);
        assert_eq!(ts.rate_series_f64(1), vec![4.0]);
    }

    #[test]
    fn empty_series() {
        let (_c, ts) = fixture();
        assert_eq!(ts.total(), 0);
        assert!(ts.rate_series().is_empty());
        assert!(ts.cumulative_series().is_empty());
    }
}
