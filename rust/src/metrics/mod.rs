//! Live metrics: counters, per-second time series, and per-message
//! completion-time recording.
//!
//! The paper's evaluation monitors exactly three quantities (§4.3): system
//! throughput (messages/second), the cumulative total of processed messages,
//! and per-message completion time. [`PipelineMetrics`] captures all three
//! with cheap atomic recording on the hot path; the [`experiment`] harness
//! snapshots them into figure series.
//!
//! [`experiment`]: crate::experiment

pub mod completion;
pub mod registry;
pub mod timeseries;

pub use completion::CompletionRecorder;
pub use registry::MetricsRegistry;
pub use timeseries::TimeSeries;

use crate::util::clock::SharedClock;
use std::sync::Arc;

/// The metric bundle every pipeline run carries.
pub struct PipelineMetrics {
    /// Count of fully processed messages, bucketed per second.
    pub processed: TimeSeries,
    /// Per-message completion time (consume → fully processed).
    pub completion: CompletionRecorder,
    /// Free-form named counters (consumed, produced, restarts, scale events…).
    pub counters: MetricsRegistry,
    pub clock: SharedClock,
}

impl PipelineMetrics {
    pub fn new(clock: SharedClock) -> Arc<Self> {
        Arc::new(PipelineMetrics {
            processed: TimeSeries::new(clock.clone()),
            completion: CompletionRecorder::new(),
            counters: MetricsRegistry::new(),
            clock,
        })
    }

    /// Record one fully-processed message and its completion latency.
    pub fn record_processed(&self, completion: std::time::Duration) {
        self.processed.record(1);
        self.completion.record(completion);
        self.counters.inc("processed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::time::Duration;

    #[test]
    fn bundle_records_all_three() {
        let clock = Arc::new(ManualClock::new());
        let m = PipelineMetrics::new(clock.clone());
        m.record_processed(Duration::from_millis(5));
        clock.advance(Duration::from_secs(1));
        m.record_processed(Duration::from_millis(15));
        assert_eq!(m.counters.get("processed"), 2);
        assert_eq!(m.processed.total(), 2);
        assert_eq!(m.completion.histogram().count(), 2);
        let cum = m.processed.cumulative_series();
        assert_eq!(cum, vec![(0, 1), (1, 2)]);
    }
}
