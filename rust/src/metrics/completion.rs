//! Per-message completion-time recording (Figure 11 / Equations 1–2).
//!
//! Completion time is defined by the paper as: the time from when a message
//! is consumed from the messaging layer until it is entirely processed in
//! the processing layer. The recorder keeps a full [`Histogram`] plus a
//! bounded reservoir of raw samples for the scatter plots.

use crate::util::histogram::Histogram;
use crate::util::prng::Pcg32;
use std::sync::Mutex;
use std::time::Duration;

const RESERVOIR: usize = 65_536;

struct Inner {
    hist: Histogram,
    samples: Vec<f64>, // seconds
    seen: u64,
    rng: Pcg32,
}

/// Thread-safe completion-time sink.
pub struct CompletionRecorder {
    inner: Mutex<Inner>,
}

impl CompletionRecorder {
    pub fn new() -> Self {
        CompletionRecorder {
            inner: Mutex::new(Inner {
                hist: Histogram::new(),
                samples: Vec::new(),
                seen: 0,
                rng: Pcg32::new(0xF16_11),
            }),
        }
    }

    pub fn record(&self, d: Duration) {
        let mut i = self.inner.lock().unwrap();
        i.hist.record(d);
        i.seen += 1;
        // Vitter's algorithm R reservoir so the raw-sample scatter stays
        // unbiased even for long runs.
        if i.samples.len() < RESERVOIR {
            i.samples.push(d.as_secs_f64());
        } else {
            let seen = i.seen as usize;
            let j = i.rng.gen_range(0, seen);
            if j < RESERVOIR {
                i.samples[j] = d.as_secs_f64();
            }
        }
    }

    pub fn histogram(&self) -> Histogram {
        self.inner.lock().unwrap().hist.clone()
    }

    /// Raw samples (seconds), reservoir-bounded.
    pub fn samples(&self) -> Vec<f64> {
        self.inner.lock().unwrap().samples.clone()
    }

    /// Mean completion time in seconds.
    pub fn mean_secs(&self) -> f64 {
        self.inner.lock().unwrap().hist.mean().as_secs_f64()
    }
}

impl Default for CompletionRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_into_both_sinks() {
        let r = CompletionRecorder::new();
        r.record(Duration::from_millis(10));
        r.record(Duration::from_millis(20));
        assert_eq!(r.histogram().count(), 2);
        assert_eq!(r.samples().len(), 2);
        assert!((r.mean_secs() - 0.015).abs() < 1e-6);
    }

    #[test]
    fn reservoir_bounds_memory() {
        let r = CompletionRecorder::new();
        for i in 0..(RESERVOIR + 1000) {
            r.record(Duration::from_micros(i as u64 + 1));
        }
        assert_eq!(r.samples().len(), RESERVOIR);
        assert_eq!(r.histogram().count() as usize, RESERVOIR + 1000);
    }
}
