//! Loader for the real T-Drive text format.
//!
//! Lines look like `1,2008-02-02 15:36:08,116.51172,39.92123`. If a local
//! copy of the dataset exists, point the experiment at its directory and
//! the pipeline replays real trajectories instead of synthetic ones.

use super::point::TrajPoint;
use std::io::BufRead;
use std::path::Path;

/// Parse `YYYY-MM-DD HH:MM:SS` to seconds since 1970-01-01 (UTC, no leap
/// seconds — standard civil arithmetic; the dataset spans one week so only
/// monotonic correctness matters).
pub fn parse_datetime(s: &str) -> Option<u64> {
    let b = s.as_bytes();
    if b.len() != 19 || b[4] != b'-' || b[7] != b'-' || b[10] != b' ' || b[13] != b':' || b[16] != b':'
    {
        return None;
    }
    let num = |r: std::ops::Range<usize>| -> Option<u64> { s.get(r)?.parse().ok() };
    let (y, mo, d) = (num(0..4)?, num(5..7)?, num(8..10)?);
    let (h, mi, sec) = (num(11..13)?, num(14..16)?, num(17..19)?);
    if !(1970..=2100).contains(&y) || !(1..=12).contains(&mo) || !(1..=31).contains(&d) {
        return None;
    }
    if h > 23 || mi > 59 || sec > 59 {
        return None;
    }
    // Days since epoch (civil-from-days inverse, Howard Hinnant's algorithm).
    let y_adj = if mo <= 2 { y - 1 } else { y };
    let era = y_adj / 400;
    let yoe = y_adj - era * 400;
    let mp = (mo + 9) % 12;
    let doy = (153 * mp + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    let days = era * 146_097 + doe - 719_468;
    Some(days * 86_400 + h * 3_600 + mi * 60 + sec)
}

/// Parse one T-Drive CSV line.
pub fn parse_tdrive_line(line: &str) -> Option<TrajPoint> {
    let mut parts = line.trim().split(',');
    let taxi_id: u32 = parts.next()?.parse().ok()?;
    let ts = parse_datetime(parts.next()?)?;
    let lon: f32 = parts.next()?.parse().ok()?;
    let lat: f32 = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None; // trailing fields: not T-Drive
    }
    Some(TrajPoint { taxi_id, ts, lon, lat })
}

/// Load every parseable point from a T-Drive file (one taxi per file in
/// the original release). Unparseable lines are skipped with a count.
pub fn load_file(path: &Path) -> std::io::Result<(Vec<TrajPoint>, usize)> {
    let f = std::fs::File::open(path)?;
    let mut points = Vec::new();
    let mut skipped = 0;
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_tdrive_line(&line) {
            Some(p) => points.push(p),
            None => skipped += 1,
        }
    }
    Ok((points, skipped))
}

/// Load all `*.txt` files under a T-Drive directory.
pub fn load_dir(dir: &Path) -> std::io::Result<Vec<TrajPoint>> {
    let mut all = Vec::new();
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.filter_map(|e| e.ok()).collect();
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.extension().map(|x| x == "txt").unwrap_or(false) {
            let (mut pts, _skipped) = load_file(&p)?;
            all.append(&mut pts);
        }
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_paper_era_datetime() {
        // 2008-02-02 00:00:00 UTC == 1201910400.
        assert_eq!(parse_datetime("2008-02-02 00:00:00"), Some(1_201_910_400));
        assert_eq!(parse_datetime("1970-01-01 00:00:00"), Some(0));
        assert_eq!(parse_datetime("1970-01-02 00:00:01"), Some(86_401));
    }

    #[test]
    fn datetime_ordering_is_monotonic() {
        let a = parse_datetime("2008-02-02 15:36:08").unwrap();
        let b = parse_datetime("2008-02-02 15:46:08").unwrap();
        assert_eq!(b - a, 600);
        let c = parse_datetime("2008-02-03 15:36:08").unwrap();
        assert_eq!(c - a, 86_400);
        // Month boundary (Feb 2008 is a leap year: 29 days).
        let feb29 = parse_datetime("2008-02-29 00:00:00").unwrap();
        let mar01 = parse_datetime("2008-03-01 00:00:00").unwrap();
        assert_eq!(mar01 - feb29, 86_400);
    }

    #[test]
    fn rejects_malformed_datetimes() {
        assert!(parse_datetime("2008-13-02 00:00:00").is_none());
        assert!(parse_datetime("2008-02-02 25:00:00").is_none());
        assert!(parse_datetime("2008-02-02T00:00:00").is_none());
        assert!(parse_datetime("garbage").is_none());
    }

    #[test]
    fn parses_tdrive_line() {
        let p = parse_tdrive_line("1,2008-02-02 15:36:08,116.51172,39.92123").unwrap();
        assert_eq!(p.taxi_id, 1);
        assert_eq!(p.lon, 116.51172);
        assert_eq!(p.lat, 39.92123);
        assert!(parse_tdrive_line("bad,line").is_none());
        assert!(parse_tdrive_line("1,2008-02-02 15:36:08,116.5,39.9,extra").is_none());
    }

    #[test]
    fn loads_file_skipping_garbage() {
        let dir = std::env::temp_dir().join(format!("rl_tdrive_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let f = dir.join("1.txt");
        std::fs::write(
            &f,
            "1,2008-02-02 15:36:08,116.51172,39.92123\nnot a line\n1,2008-02-02 15:46:08,116.52,39.93\n",
        )
        .unwrap();
        let (pts, skipped) = load_file(&f).unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(skipped, 1);
        let all = load_dir(&dir).unwrap();
        assert_eq!(all.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
