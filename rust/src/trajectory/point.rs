//! GPS points and their wire format.

/// One GPS fix. Coordinates are WGS84 degrees; `ts` is seconds since the
/// dataset epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrajPoint {
    pub taxi_id: u32,
    pub ts: u64,
    pub lon: f32,
    pub lat: f32,
}

/// Wire size of an encoded point.
pub const POINT_BYTES: usize = 4 + 8 + 4 + 4;

impl TrajPoint {
    /// Encode to the 20-byte wire format used as message payloads.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(POINT_BYTES);
        out.extend_from_slice(&self.taxi_id.to_le_bytes());
        out.extend_from_slice(&self.ts.to_le_bytes());
        out.extend_from_slice(&self.lon.to_le_bytes());
        out.extend_from_slice(&self.lat.to_le_bytes());
        out
    }

    /// Decode from the wire format.
    pub fn decode(b: &[u8]) -> Option<TrajPoint> {
        if b.len() != POINT_BYTES {
            return None;
        }
        Some(TrajPoint {
            taxi_id: u32::from_le_bytes(b[0..4].try_into().ok()?),
            ts: u64::from_le_bytes(b[4..12].try_into().ok()?),
            lon: f32::from_le_bytes(b[12..16].try_into().ok()?),
            lat: f32::from_le_bytes(b[16..20].try_into().ok()?),
        })
    }

    /// Position as the `[lon, lat]` pair TCMM clusters on.
    pub fn xy(&self) -> [f32; 2] {
        [self.lon, self.lat]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_round_trip() {
        let p = TrajPoint { taxi_id: 42, ts: 1_202_000_000, lon: 116.51172, lat: 39.92123 };
        let enc = p.encode();
        assert_eq!(enc.len(), POINT_BYTES);
        assert_eq!(TrajPoint::decode(&enc), Some(p));
    }

    #[test]
    fn decode_wrong_len_none() {
        assert_eq!(TrajPoint::decode(&[0u8; 5]), None);
        assert_eq!(TrajPoint::decode(&[]), None);
    }

    #[test]
    fn round_trip_property() {
        crate::util::propcheck::check("point-codec", 100, |g| {
            let p = TrajPoint {
                taxi_id: g.u64() as u32,
                ts: g.u64(),
                lon: (g.f64() * 360.0 - 180.0) as f32,
                lat: (g.f64() * 180.0 - 90.0) as f32,
            };
            crate::prop_assert!(TrajPoint::decode(&p.encode()) == Some(p), "round trip");
            Ok(())
        });
    }
}
