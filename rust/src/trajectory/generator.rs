//! Synthetic T-Drive generator.
//!
//! Taxis move between spatial hot-spots inside the Beijing bounding box:
//! each taxi dwells near a hot-spot (Gaussian jitter ≈ a few hundred
//! metres), then with some probability transits to another hot-spot.
//! Sampling period ≈ 177 s matches the real dataset's mean. The hot-spot
//! structure is what gives TCMM non-trivial micro-/macro-clusters.

use super::point::TrajPoint;
use crate::util::prng::Pcg32;

/// Beijing bounding box (matches the T-Drive coverage area).
pub const LON_RANGE: (f32, f32) = (116.0, 116.8);
pub const LAT_RANGE: (f32, f32) = (39.6, 40.2);

/// Streaming generator: yields points taxi-by-taxi in timestamp order per
/// taxi (the real dataset is one file per taxi, also time-ordered).
pub struct TrajectoryGenerator {
    rng: Pcg32,
    hotspots: Vec<[f32; 2]>,
    /// Per-taxi state: (current hotspot, lon, lat, ts).
    taxis: Vec<TaxiState>,
    /// Mean seconds between fixes.
    period: f64,
    /// Probability of hopping hot-spots between fixes.
    hop_prob: f64,
    /// Std-dev of dwell jitter in degrees (~0.005° ≈ 500 m).
    jitter: f64,
}

struct TaxiState {
    hotspot: usize,
    lon: f32,
    lat: f32,
    ts: u64,
}

impl TrajectoryGenerator {
    pub fn new(taxis: usize, hotspots: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let hotspots: Vec<[f32; 2]> = (0..hotspots.max(1))
            .map(|_| {
                [
                    LON_RANGE.0 + rng.f32() * (LON_RANGE.1 - LON_RANGE.0),
                    LAT_RANGE.0 + rng.f32() * (LAT_RANGE.1 - LAT_RANGE.0),
                ]
            })
            .collect();
        let taxis = (0..taxis)
            .map(|_| {
                let h = rng.gen_range(0, hotspots.len());
                TaxiState { hotspot: h, lon: hotspots[h][0], lat: hotspots[h][1], ts: 0 }
            })
            .collect();
        TrajectoryGenerator { rng, hotspots, taxis, period: 177.0, hop_prob: 0.05, jitter: 0.005 }
    }

    pub fn hotspots(&self) -> &[[f32; 2]] {
        &self.hotspots
    }

    /// Next fix for taxi `id`.
    pub fn next_point(&mut self, id: usize) -> TrajPoint {
        let n_hot = self.hotspots.len();
        let hop = self.rng.chance(self.hop_prob);
        let jl = (self.rng.normal() * self.jitter) as f32;
        let jt = (self.rng.normal() * self.jitter) as f32;
        let dt = self.rng.exponential(1.0 / self.period).max(1.0) as u64;
        let t = &mut self.taxis[id];
        if hop {
            t.hotspot = self.rng.gen_range(0, n_hot);
        }
        let h = self.hotspots[t.hotspot];
        t.lon = (h[0] + jl).clamp(LON_RANGE.0, LON_RANGE.1);
        t.lat = (h[1] + jt).clamp(LAT_RANGE.0, LAT_RANGE.1);
        t.ts += dt;
        TrajPoint { taxi_id: id as u32, ts: t.ts, lon: t.lon, lat: t.lat }
    }

    /// Generate a full workload: `points_per_taxi` fixes for every taxi,
    /// interleaved round-robin (arrival order ≈ time order, like a live
    /// feed).
    pub fn generate(&mut self, points_per_taxi: usize) -> Vec<TrajPoint> {
        let n = self.taxis.len();
        let mut out = Vec::with_capacity(n * points_per_taxi);
        for _ in 0..points_per_taxi {
            for id in 0..n {
                out.push(self.next_point(id));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_inside_bbox_and_time_ordered() {
        let mut g = TrajectoryGenerator::new(5, 3, 11);
        let pts = g.generate(50);
        assert_eq!(pts.len(), 250);
        for p in &pts {
            assert!((LON_RANGE.0..=LON_RANGE.1).contains(&p.lon), "lon {}", p.lon);
            assert!((LAT_RANGE.0..=LAT_RANGE.1).contains(&p.lat), "lat {}", p.lat);
        }
        // Per-taxi timestamps strictly increase.
        for taxi in 0..5u32 {
            let ts: Vec<u64> = pts.iter().filter(|p| p.taxi_id == taxi).map(|p| p.ts).collect();
            assert!(ts.windows(2).all(|w| w[0] < w[1]), "taxi {taxi} times not increasing");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TrajectoryGenerator::new(3, 2, 7).generate(10);
        let b = TrajectoryGenerator::new(3, 2, 7).generate(10);
        assert_eq!(a, b);
        let c = TrajectoryGenerator::new(3, 2, 8).generate(10);
        assert_ne!(a, c);
    }

    #[test]
    fn clustered_structure_exists() {
        // Most points should lie near SOME hotspot (within 3 jitter sigmas
        // ≈ 0.015°) — this is what TCMM will discover.
        let mut g = TrajectoryGenerator::new(20, 4, 3);
        let hotspots = g.hotspots().to_vec();
        let pts = g.generate(100);
        let near = pts
            .iter()
            .filter(|p| {
                hotspots.iter().any(|h| {
                    let dx = p.lon - h[0];
                    let dy = p.lat - h[1];
                    (dx * dx + dy * dy).sqrt() < 0.015
                })
            })
            .count();
        let frac = near as f64 / pts.len() as f64;
        assert!(frac > 0.9, "only {frac} of points near hotspots");
    }
}
