//! Trajectory workload: T-Drive-style GPS points.
//!
//! The paper evaluates on the T-Drive Beijing taxi dataset (Yuan et al.,
//! 10,357 taxis, Feb 2–8 2008). That dataset is not redistributable here,
//! so [`generator`] synthesizes trajectories with the same schema, spatial
//! extent (Beijing bounding box) and *clustered* structure (taxis orbit
//! hot-spots — what makes TCMM's micro-clusters meaningful), while
//! [`loader`] parses the real T-Drive text format
//! (`taxi_id,YYYY-MM-DD HH:MM:SS,longitude,latitude`) if a copy is
//! available locally. Either source yields the same [`TrajPoint`]s.

pub mod generator;
pub mod loader;
pub mod point;

pub use generator::TrajectoryGenerator;
pub use loader::parse_tdrive_line;
pub use point::TrajPoint;
