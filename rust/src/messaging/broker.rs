//! The broker: topics + consumer-group coordinator + consumer handles.
//!
//! Three structural choices keep the hot path fast under many concurrent
//! producers/consumers (the elastic swings of §4):
//!
//! - the topic registry is **sharded**: topic names hash to one of
//!   [`TOPIC_SHARDS`] independent `RwLock<HashMap>` shards, so topic
//!   lookups from different pipelines never contend on one global lock;
//! - the **data plane and the coordinator are locked separately**:
//!   partition logs are lock-free to read ([`PartitionLog`]), and each
//!   consumer group has its *own* coordinator mutex — `poll`/`poll_batch`
//!   snapshot assignment + positions under the group lock, read the logs
//!   with **no lock held**, then re-acquire (generation-checked) to
//!   advance, so consumers of different groups on one topic never
//!   serialize on each other and a slow partition read blocks nobody;
//! - every data-plane operation has a **batch-first** variant
//!   ([`Topic::publish_batch`], [`Consumer::poll_batch`],
//!   [`Consumer::commit_batch`]) that pays each coordination cost once per
//!   batch instead of once per message — the `n`-message consume cycle of
//!   Eq. 1 (`T = n·t_c + i·t_p`) made explicit in the API.
//!
//! Lag probes ([`Broker::group_lag`], [`Broker::total_lag`]) are polled
//! every controller tick and every drain-watermark check, so they bypass
//! the coordinator entirely: each topic counts messages `published` and
//! each group mirrors its `committed` total into an atomic, making a lag
//! probe O(groups) atomic loads.

//!
//! A broker opened with [`Broker::with_storage`] additionally writes
//! every partition through a durable [`Storage`] backend and checkpoints
//! committed offsets, recovering both on startup; `Broker::new` stays
//! purely in-memory. The data-plane protocol is unchanged either way —
//! persistence rides inside the partition writer mutex
//! ([`PartitionLog::attach_store`]) and behind the commit paths.

use super::group::{GroupState, MemberId};
use super::message::{Message, OffsetMessage};
use super::partition::{BatchRef, PartitionLog};
use super::storage::{Storage, StorageError};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Coordination state of one consumer group, individually locked. The
/// committed-offset total is mirrored outside the mutex so lag probes are
/// atomic loads, never coordinator acquisitions.
struct GroupHandle {
    /// The group's name, for checkpointing commits to storage.
    name: String,
    state: Mutex<GroupState>,
    /// Sum of committed offsets across partitions (monotonic — commits
    /// never regress). `published - committed_total` is the group's lag.
    committed_total: AtomicU64,
}

impl GroupHandle {
    fn new(name: &str, partitions: usize) -> Self {
        GroupHandle {
            name: name.to_string(),
            state: Mutex::new(GroupState::new(partitions)),
            committed_total: AtomicU64::new(0),
        }
    }
}

/// One topic: partition logs plus per-group coordination state.
pub struct Topic {
    pub name: String,
    partitions: Vec<PartitionLog>,
    /// group name → its coordinator. The registry lock covers only
    /// lookup/insert; all coordination runs under the per-group mutex, so
    /// groups on the same topic never contend with each other.
    groups: RwLock<HashMap<String, Arc<GroupHandle>>>,
    /// Round-robin cursor for keyless produces.
    rr: AtomicUsize,
    /// Messages ever published to this topic (all partitions). Paired
    /// with each group's `committed_total` this makes lag a subtraction
    /// of two atomic loads.
    published: AtomicU64,
    /// Durable backend, when the broker was opened with one. Commits are
    /// checkpointed through it; the partition logs write through their
    /// attached stores independently.
    storage: Option<Arc<dyn Storage>>,
}

impl Topic {
    fn new(name: &str, partitions: usize) -> Self {
        assert!(partitions >= 1, "topic needs >= 1 partition");
        Topic {
            name: name.to_string(),
            partitions: (0..partitions).map(|_| PartitionLog::new()).collect(),
            groups: RwLock::new(HashMap::new()),
            rr: AtomicUsize::new(0),
            published: AtomicU64::new(0),
            storage: None,
        }
    }

    /// Build a durable topic: open every partition's store, replay what
    /// it recovered into the in-memory log, and attach the store so new
    /// appends write through. Used for both fresh creation (the stores
    /// recover nothing) and restart recovery.
    fn recover(name: &str, partitions: usize, storage: Arc<dyn Storage>) -> Result<Self, StorageError> {
        assert!(partitions >= 1, "topic needs >= 1 partition");
        let mut logs = Vec::with_capacity(partitions);
        let mut published = 0u64;
        for p in 0..partitions {
            let (store, recovered) = storage.open_partition(name, p)?;
            let log = PartitionLog::new();
            published += recovered.len() as u64;
            log.restore(recovered);
            log.attach_store(store);
            logs.push(log);
        }
        Ok(Topic {
            name: name.to_string(),
            partitions: logs,
            groups: RwLock::new(HashMap::new()),
            rr: AtomicUsize::new(0),
            published: AtomicU64::new(published),
            storage: Some(storage),
        })
    }

    /// Forward commit watermarks that actually moved to the checkpoint
    /// store. Called outside the group lock — the store applies entries
    /// monotonically, so a racing stale checkpoint can never regress one.
    fn checkpoint_commits(&self, group: &str, entries: &[(usize, u64)]) {
        if let Some(storage) = &self.storage {
            storage.checkpoint(&self.name, group, entries);
        }
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Total messages across partitions.
    pub fn end_offsets(&self) -> Vec<u64> {
        self.partitions.iter().map(|p| p.end_offset()).collect()
    }

    pub fn total_messages(&self) -> u64 {
        self.end_offsets().iter().sum()
    }

    /// Names of consumer groups coordinated on this topic (sorted).
    pub fn group_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.groups.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Existing coordinator for `group`, if any.
    fn group(&self, group: &str) -> Option<Arc<GroupHandle>> {
        self.groups.read().unwrap().get(group).cloned()
    }

    /// Coordinator for `group`, created on first use. Registry write lock
    /// is taken only on the miss path (group creation is rare; joins to
    /// an existing group stay on the read lock).
    fn group_or_create(&self, group: &str) -> Arc<GroupHandle> {
        if let Some(h) = self.group(group) {
            return h;
        }
        let mut groups = self.groups.write().unwrap();
        groups
            .entry(group.to_string())
            .or_insert_with(|| Arc::new(GroupHandle::new(group, self.partition_count())))
            .clone()
    }

    /// Partition a message lands in: key hash when keyed, else the next
    /// round-robin slot.
    fn pick_partition(&self, key: Option<u64>) -> usize {
        match key {
            Some(k) => partition_for_key(k, self.partitions.len()),
            None => self.rr.fetch_add(1, Ordering::Relaxed) % self.partitions.len(),
        }
    }

    /// Publish, choosing the partition from the key hash (or round-robin).
    pub fn publish(&self, msg: Message) -> (usize, u64) {
        let p = self.pick_partition(msg.key);
        // Count before the append publishes the message: a racing lag
        // probe may transiently over-report (safe — it re-polls), but can
        // never read "drained" while an appended message is unconsumed.
        self.published.fetch_add(1, Ordering::Relaxed);
        let off = self.partitions[p].append(msg);
        (p, off)
    }

    /// Publish a batch, paying each partition's append cost once.
    ///
    /// Semantics match a sequence of [`Topic::publish`] calls exactly:
    /// keyed messages go to their key's partition, keyless messages
    /// round-robin, and *input order is preserved within every partition*
    /// (so per-key ordering holds across batch boundaries). Returns the
    /// `(partition, offset)` of every message, in input order.
    ///
    /// Batches that touch a single partition (1-partition topics, hot
    /// keyed batches) skip bucketing entirely and append the input vector
    /// as-is; the general path sizes each partition's bucket exactly, so
    /// untouched partitions never allocate.
    pub fn publish_batch(&self, msgs: Vec<Message>) -> Vec<(usize, u64)> {
        let n = self.partitions.len();
        let len = msgs.len();
        if len == 0 {
            return Vec::new();
        }
        // Count the whole batch before any append publishes a message
        // (see `publish`: lag may transiently over-report, never read
        // "drained" while appended messages are unconsumed).
        self.published.fetch_add(len as u64, Ordering::Relaxed);
        // Fast path: a 1-partition topic is one dense append, no routing.
        if n == 1 {
            let base = self.partitions[0].append_batch(msgs);
            return (0..len as u64).map(|i| (0, base + i)).collect();
        }
        // Reserve one contiguous run of round-robin slots for the batch's
        // keyless messages, then route each message in input order.
        let keyless = msgs.iter().filter(|m| m.key.is_none()).count();
        let mut rr = if keyless > 0 { self.rr.fetch_add(keyless, Ordering::Relaxed) } else { 0 };
        let mut which = Vec::with_capacity(len);
        for m in &msgs {
            let p = match m.key {
                Some(k) => partition_for_key(k, n),
                None => {
                    let p = rr % n;
                    rr += 1;
                    p
                }
            };
            which.push(p);
        }
        // Fast path: every message landed on one partition (same-key hot
        // batches) — append the input vector directly, no buckets.
        let first = which[0];
        if which.iter().all(|&p| p == first) {
            let base = self.partitions[first].append_batch(msgs);
            return (0..len as u64).map(|i| (first, base + i)).collect();
        }
        // General path: bucket per partition in input order. Exact-size
        // buckets — only touched partitions allocate, and never regrow.
        let mut counts = vec![0usize; n];
        for &p in &which {
            counts[p] += 1;
        }
        let mut buckets: Vec<Vec<Message>> = counts.into_iter().map(Vec::with_capacity).collect();
        for (m, &p) in msgs.into_iter().zip(which.iter()) {
            buckets[p].push(m);
        }
        // One append (one tail publish) per touched partition.
        let mut next = vec![0u64; n];
        for (p, bucket) in buckets.into_iter().enumerate() {
            if !bucket.is_empty() {
                next[p] = self.partitions[p].append_batch(bucket);
            }
        }
        which
            .into_iter()
            .map(|p| {
                let off = next[p];
                next[p] += 1;
                (p, off)
            })
            .collect()
    }

    /// Clustered publish: append `msgs` to one **explicit** partition,
    /// bypassing key/round-robin routing — the cluster client already
    /// routed (with [`partition_for_key`], so client-side and in-process
    /// routing agree) and the owner check in the wire server already
    /// vetted that this node holds `partition`. Returns the base offset
    /// of the appended run (input order preserved; offsets are dense).
    pub fn publish_to(&self, partition: usize, msgs: Vec<Message>) -> u64 {
        let log = &self.partitions[partition];
        if msgs.is_empty() {
            return log.end_offset();
        }
        // Count before the append, as in `publish` — lag may transiently
        // over-report, never read "drained" with unconsumed messages.
        self.published.fetch_add(msgs.len() as u64, Ordering::Relaxed);
        log.append_batch(msgs)
    }

    /// Replica-side conditional append: apply a batch claimed to start at
    /// `base`, idempotently against the partition's current end. The
    /// duplicate/overlap/gap check and the append run under the partition
    /// log's own writer lock ([`PartitionLog::append_batch_from`]), so
    /// two concurrent replica streams — a live forward and a catch-up
    /// pull — can never both pass the check and fork the log. Returns the
    /// partition's end offset after the call (the replica ack value).
    pub fn publish_to_at(&self, partition: usize, base: u64, msgs: Vec<Message>) -> u64 {
        let log = &self.partitions[partition];
        let n = msgs.len() as u64;
        if n == 0 {
            return log.end_offset();
        }
        // Count before the append (same over-report-only direction as
        // `publish`), then give back whatever the log skipped as already
        // held or gapped — a duplicate apply must not inflate lag.
        self.published.fetch_add(n, Ordering::Relaxed);
        let (end, appended) = log.append_batch_from(base, msgs);
        if appended < n {
            self.published.fetch_sub(n - appended, Ordering::Relaxed);
        }
        end
    }

    /// Read a raw window from one partition (offset-addressed, group-free).
    pub fn read(&self, partition: usize, from: u64, max: usize) -> Vec<(u64, Message)> {
        self.partitions[partition].read(from, max)
    }

    /// Lag of one group: published minus committed, two atomic loads. A
    /// group that was never created lags by everything published.
    ///
    /// Load order matters: `committed_total` is read *first* (acquire,
    /// pairing with the release fetch_add on the commit paths). A commit
    /// can only cover messages whose publish was counted first, so a
    /// `published` value loaded afterwards includes every publish behind
    /// the observed commits — lag may transiently over-report while a
    /// probe races producers, but can never read 0 with an appended
    /// message unconsumed.
    fn group_lag(&self, group: &str) -> u64 {
        match self.group(group) {
            None => self.published.load(Ordering::Relaxed),
            Some(h) => {
                let committed = h.committed_total.load(Ordering::Acquire);
                self.published.load(Ordering::Relaxed).saturating_sub(committed)
            }
        }
    }

    /// Sum of every group's lag on this topic — O(groups) atomic loads
    /// under one registry read lock. A topic with no groups contributes 0
    /// (nobody is behind). Same load order as [`Topic::group_lag`]:
    /// committed before published, per group.
    fn lag_sum(&self) -> u64 {
        let groups = self.groups.read().unwrap();
        groups
            .values()
            .map(|h| {
                let committed = h.committed_total.load(Ordering::Acquire);
                self.published.load(Ordering::Relaxed).saturating_sub(committed)
            })
            .sum()
    }
}

#[inline]
fn hash64(mut x: u64) -> u64 {
    // SplitMix64 finalizer as a cheap, well-mixed hash.
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// The partition a keyed message lands in — **the** routing function,
/// public so the cluster client partitions on its side of the wire with
/// bit-identical results to an in-process publish.
#[inline]
pub fn partition_for_key(key: u64, partitions: usize) -> usize {
    (hash64(key) % partitions as u64) as usize
}

/// Conservative per-message wire cost used by byte-budgeted polls: the
/// payload plus a fixed allowance for framing (key tag + key + timestamp
/// + partition + offset + length prefixes). Matches the publish-side
/// chunking estimate in the remote client, so both directions budget the
/// same way.
#[inline]
pub fn wire_cost(m: &Message) -> usize {
    m.payload.len() + 32
}

/// Number of independent topic-registry shards. Power of two so the name
/// hash folds with a mask; 16 is comfortably above the topic-touching
/// thread counts the experiment grid produces.
const TOPIC_SHARDS: usize = 16;

#[inline]
fn shard_of(name: &str) -> usize {
    // FNV-1a over the name bytes, folded into the shard mask.
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    (h as usize) & (TOPIC_SHARDS - 1)
}

/// The in-process broker (the messaging layer).
///
/// The topic map is split into [`TOPIC_SHARDS`] lock shards keyed by the
/// topic-name hash: producers and consumer groups on different topics take
/// different locks, so registry lookups scale with the pipeline width
/// instead of serializing on one `RwLock`.
pub struct Broker {
    shards: [RwLock<HashMap<String, Arc<Topic>>>; TOPIC_SHARDS],
    next_member: AtomicU64,
    /// Durable backend, when opened with [`Broker::with_storage`].
    storage: Option<Arc<dyn Storage>>,
}

impl Broker {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Open a broker on a durable [`Storage`] backend and recover
    /// everything it persisted: topics are re-created from the manifest,
    /// each partition replays its segment log (torn tails already
    /// truncated by the backend), and consumer groups resume from their
    /// checkpointed committed offsets (clamped to the recovered log end —
    /// redelivery, never loss). Errors mean the on-disk state cannot be
    /// trusted; the caller should refuse to serve rather than start empty.
    pub fn with_storage(storage: Arc<dyn Storage>) -> Result<Arc<Self>, StorageError> {
        let broker = Broker {
            shards: std::array::from_fn(|_| RwLock::new(HashMap::new())),
            next_member: AtomicU64::new(1),
            storage: Some(storage.clone()),
        };
        for meta in storage.load_topics()? {
            broker.try_create_topic(&meta.name, meta.partitions)?;
        }
        for c in storage.load_commits() {
            let Some(t) = broker.topic(&c.topic) else {
                crate::log_warn!(
                    "storage",
                    "checkpoint names unknown topic '{}' (group '{}'); ignored",
                    c.topic,
                    c.group
                );
                continue;
            };
            if c.partition >= t.partition_count() {
                crate::log_warn!(
                    "storage",
                    "checkpoint for '{}' names partition {} of {}; ignored",
                    c.topic,
                    c.partition,
                    t.partition_count()
                );
                continue;
            }
            // Clamp to the recovered end: a checkpoint that outran a
            // truncated log must redeliver, not mask real lag.
            let end = t.partitions[c.partition].end_offset();
            let h = t.group_or_create(&c.group);
            let delta = h.state.lock().unwrap().commit(c.partition, c.next.min(end));
            if delta > 0 {
                h.committed_total.fetch_add(delta, Ordering::Release);
            }
        }
        Ok(Arc::new(broker))
    }

    fn shard(&self, name: &str) -> &RwLock<HashMap<String, Arc<Topic>>> {
        &self.shards[shard_of(name)]
    }

    /// Create a topic (idempotent; partition count must match an existing
    /// topic or the call panics — config error, as does a storage failure).
    pub fn create_topic(&self, name: &str, partitions: usize) -> Arc<Topic> {
        self.try_create_topic(name, partitions)
            .unwrap_or_else(|e| panic!("create topic '{name}': {e}"))
    }

    /// Fallible [`Broker::create_topic`]: durable brokers surface storage
    /// refusals (partition-count mismatch with persisted state, damaged
    /// segment chains) instead of panicking.
    pub fn try_create_topic(&self, name: &str, partitions: usize) -> Result<Arc<Topic>, StorageError> {
        let mut t = self.shard(name).write().unwrap();
        if let Some(topic) = t.get(name) {
            assert_eq!(
                topic.partition_count(),
                partitions,
                "topic '{name}' exists with different partition count"
            );
            return Ok(topic.clone());
        }
        let topic = match &self.storage {
            None => Arc::new(Topic::new(name, partitions)),
            Some(storage) => {
                storage.create_topic(name, partitions)?;
                Arc::new(Topic::recover(name, partitions, storage.clone())?)
            }
        };
        t.insert(name.to_string(), topic.clone());
        Ok(topic)
    }

    /// The durable backend, if this broker has one (`rl-node` uses it for
    /// a final sync on graceful shutdown).
    pub fn storage(&self) -> Option<&Arc<dyn Storage>> {
        self.storage.as_ref()
    }

    pub fn topic(&self, name: &str) -> Option<Arc<Topic>> {
        self.shard(name).read().unwrap().get(name).cloned()
    }

    /// Names of all topics, across shards (sorted; for reports/debugging).
    pub fn topic_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().keys().cloned().collect::<Vec<_>>())
            .collect();
        names.sort();
        names
    }

    fn expect_topic(&self, name: &str) -> Arc<Topic> {
        self.topic(name).unwrap_or_else(|| panic!("unknown topic '{name}'"))
    }

    /// Join `group` on `topic`, returning a consumer handle. The handle
    /// caches the group's coordinator `Arc`, so its whole data plane —
    /// poll, commit, leave — never touches the topic's group registry
    /// again. It leaves the group on [`Consumer::close`] or drop (crash
    /// semantics: dropping without commit rewinds the group to the
    /// committed offsets).
    pub fn subscribe(&self, topic: &str, group: &str) -> Consumer {
        let t = self.expect_topic(topic);
        let member = self.next_member.fetch_add(1, Ordering::Relaxed);
        let handle = t.group_or_create(group);
        handle.state.lock().unwrap().join(member);
        Consumer {
            topic: t,
            group: handle,
            member,
            open: true,
            cursor: AtomicUsize::new(0),
        }
    }

    /// Number of members currently in `group` on `topic`.
    pub fn group_members(&self, topic: &str, group: &str) -> usize {
        let t = self.expect_topic(topic);
        t.group(group).map(|h| h.state.lock().unwrap().member_count()).unwrap_or(0)
    }

    /// Committed offset for `(topic, group, partition)`.
    pub fn committed(&self, topic: &str, group: &str, partition: usize) -> u64 {
        let t = self.expect_topic(topic);
        t.group(group).map(|h| h.state.lock().unwrap().committed(partition)).unwrap_or(0)
    }

    /// Sum of unconsumed (past committed) messages for a group — the lag
    /// the elastic-worker service watches every tick. Two atomic loads;
    /// no coordinator lock, so even a poll-heavy group can be probed at
    /// any frequency without slowing its consumers.
    pub fn group_lag(&self, topic: &str, group: &str) -> u64 {
        self.expect_topic(topic).group_lag(group)
    }

    /// Sum of [`Broker::group_lag`] over every (topic, group) pair — zero
    /// means every group has consumed and committed everything published.
    /// This is the drain watermark the experiment runner gates on: one
    /// registry read-lock sweep per shard, O(groups) atomic loads per
    /// topic, no per-topic name re-resolution and no coordinator locks.
    pub fn total_lag(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().values().map(|t| t.lag_sum()).sum::<u64>())
            .sum()
    }

    /// Run [`GroupState::check_invariants`] for `(topic, group)`. Test
    /// hook for the concurrent-churn property suite; a group that does
    /// not exist yet trivially holds.
    pub fn check_group_invariants(&self, topic: &str, group: &str) -> Result<(), String> {
        let t = self.expect_topic(topic);
        match t.group(group) {
            None => Ok(()),
            Some(h) => h.state.lock().unwrap().check_invariants(),
        }
    }
}

/// One poll's worth of messages plus the commit bookkeeping for it.
///
/// `next_offsets` is the per-partition high-watermark (`partition`,
/// `next offset to read`) covering everything in `messages`;
/// [`Consumer::commit_batch`] applies all of them under a single
/// coordinator lock. `generation` is the group's rebalance generation at
/// poll time — a commit from a batch polled *before* a rebalance is
/// fenced (dropped), so ownership changes always rewind to the committed
/// offset and redeliver, keeping delivery at-least-once.
pub struct PolledBatch {
    pub messages: Vec<OffsetMessage>,
    pub next_offsets: Vec<(usize, u64)>,
    pub generation: u64,
}

impl PolledBatch {
    pub fn len(&self) -> usize {
        self.messages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.messages.is_empty()
    }
}

/// The zero-copy counterpart of [`PolledBatch`]: per-partition shared
/// slices into the partition logs instead of cloned messages.
///
/// Each [`BatchRef`] pins its log segments alive via `Arc`, so the wire
/// server can encode a reply straight from log memory — no `Message`
/// clone, no payload refcount churn — and drop the batch afterwards.
/// `next_offsets` / `generation` carry the same commit bookkeeping as
/// `PolledBatch`; [`PolledBatchRef::to_polled_batch`] materializes an
/// owned batch for callers that need one (commits only read the
/// bookkeeping fields, so the two forms commit identically).
pub struct PolledBatchRef {
    /// `(partition, slices)` in delivery order; empty partitions are
    /// omitted. Within each partition, messages are in offset order.
    pub parts: Vec<(usize, BatchRef)>,
    pub next_offsets: Vec<(usize, u64)>,
    pub generation: u64,
}

impl PolledBatchRef {
    pub fn len(&self) -> usize {
        self.parts.iter().map(|(_, b)| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.iter().all(|(_, b)| b.is_empty())
    }

    /// Iterate `(partition, offset, &message)` in delivery order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, u64, &Message)> {
        self.parts
            .iter()
            .flat_map(|(p, b)| b.iter().map(move |(o, m)| (*p, o, m)))
    }

    /// Materialize into an owned [`PolledBatch`] (clones bump payload
    /// refcounts, not bytes). The compatibility bridge for the in-process
    /// poll API.
    pub fn to_polled_batch(&self) -> PolledBatch {
        let messages = self
            .iter()
            .map(|(partition, offset, message)| OffsetMessage {
                partition,
                offset,
                message: message.clone(),
            })
            .collect();
        PolledBatch {
            messages,
            next_offsets: self.next_offsets.clone(),
            generation: self.generation,
        }
    }
}

/// A consumer-group member handle.
///
/// `poll`/`poll_batch` read batches from the member's assigned partitions
/// and advance the group's in-memory positions; `commit`/`commit_batch`
/// durably record progress so a restarted member resumes there. Dropping
/// without closing mimics a crash.
///
/// Both poll paths follow the snapshot / read / advance protocol: the
/// group lock is held only to copy assignment + positions and (again,
/// generation-checked) to advance them afterwards — **the partition-log
/// reads in between run with no lock held**, so members of one group, and
/// entire other groups, proceed in parallel with them. A rebalance that
/// lands between snapshot and advance fences the advance (positions
/// re-seeded from committed offsets win), and the already-returned batch
/// is fenced at commit time by its stale generation — exactly the
/// at-least-once redelivery the single-lock implementation had.
pub struct Consumer {
    topic: Arc<Topic>,
    group: Arc<GroupHandle>,
    member: MemberId,
    open: bool,
    /// Rotates which owned partition each poll visits first, so a small
    /// `max` drains all partitions fairly instead of starving the
    /// highest-numbered ones behind partition 0.
    cursor: AtomicUsize,
}

impl Consumer {
    pub fn member_id(&self) -> MemberId {
        self.member
    }

    pub fn topic_name(&self) -> &str {
        &self.topic.name
    }

    /// Partitions this member currently owns.
    pub fn assignment(&self) -> Vec<usize> {
        self.group.state.lock().unwrap().assigned(self.member).to_vec()
    }

    /// Copy generation + assignment + positions under the group lock —
    /// everything a poll needs before it lets go of the coordinator.
    fn snapshot(&self) -> (u64, Vec<usize>, Vec<u64>) {
        let g = self.group.state.lock().unwrap();
        let parts = g.assigned(self.member).to_vec();
        let positions = parts.iter().map(|&p| g.position(p)).collect();
        (g.generation(), parts, positions)
    }

    /// Re-acquire the coordinator and advance positions, unless the group
    /// rebalanced since `generation` was snapshotted (the re-seeded
    /// positions then stand, and the caller's batch commit will be
    /// fenced).
    fn advance_if_current(&self, generation: u64, advances: &[(usize, u64)]) {
        if advances.is_empty() {
            return;
        }
        let mut g = self.group.state.lock().unwrap();
        if g.generation() == generation {
            for &(p, next) in advances {
                g.advance(p, next);
            }
        }
    }

    /// The shared snapshot → lock-free read → fenced advance cycle behind
    /// every poll flavor. Returns the polled batch with its watermarks
    /// and generation; `poll` discards the bookkeeping, `poll_batch`
    /// returns it for fenced commits.
    ///
    /// `max_bytes` bounds the batch by [`wire_cost`]: positions advance
    /// only over the kept prefix, so budget-trimmed messages are simply
    /// re-read by the next poll, never skipped. **Progress guarantee:**
    /// the first message of a poll is always delivered, even when it
    /// alone overruns the budget — a poll can be oversized, but can never
    /// livelock returning empty against a large head-of-line message.
    fn poll_inner(&self, max: usize, max_bytes: usize) -> PolledBatch {
        self.poll_refs_inner(max, max_bytes).to_polled_batch()
    }

    /// The zero-copy core behind every poll flavor: identical snapshot /
    /// rotation / budget / advance semantics to the historical owned
    /// `poll_inner`, but the messages stay where they are — each
    /// partition contributes a [`BatchRef`] of shared log slices, trimmed
    /// with [`BatchRef::truncate`] to the budget-kept prefix.
    fn poll_refs_inner(&self, max: usize, max_bytes: usize) -> PolledBatchRef {
        let mut out: Vec<(usize, BatchRef)> = Vec::new();
        let mut next_offsets: Vec<(usize, u64)> = Vec::new();
        let (generation, parts, positions) = self.snapshot();
        if parts.is_empty() || max == 0 {
            return PolledBatchRef { parts: out, next_offsets, generation };
        }
        let mut budget = max_bytes;
        let mut total = 0usize;
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % parts.len();
        for k in 0..parts.len() {
            if total >= max {
                break;
            }
            let i = (start + k) % parts.len();
            let (p, from) = (parts[i], positions[i]);
            let mut batch = self.topic.partitions[p].read_ref(from, max - total);
            let mut last: Option<u64> = None;
            let mut kept = 0usize;
            let mut exhausted = false;
            for (offset, message) in batch.iter() {
                let cost = wire_cost(message);
                if cost > budget && total + kept > 0 {
                    exhausted = true;
                    break;
                }
                budget = budget.saturating_sub(cost);
                last = Some(offset);
                kept += 1;
            }
            batch.truncate(kept);
            total += kept;
            if let Some(l) = last {
                next_offsets.push((p, l + 1));
            }
            if kept > 0 {
                out.push((p, batch));
            }
            if exhausted {
                break;
            }
        }
        self.advance_if_current(generation, &next_offsets);
        PolledBatchRef { parts: out, next_offsets, generation }
    }

    /// Poll up to `max` messages across owned partitions (rotating the
    /// starting partition per poll, batch per partition). Non-blocking:
    /// may return empty. Shares [`Consumer::poll_batch`]'s snapshot →
    /// read → advance cycle and simply discards the watermark/generation
    /// bookkeeping; the paths differ only in their *commit* side — pair
    /// this one with per-message [`Consumer::commit`] calls, which is
    /// what `perf_hotpath` measures against the batched pair.
    pub fn poll(&self, max: usize) -> Vec<OffsetMessage> {
        self.poll_inner(max, usize::MAX).messages
    }

    /// Poll up to `max` messages and return them together with the
    /// per-partition commit watermarks and the group generation — the
    /// batch-first consume path. The coordinator is held only for the
    /// position snapshot and the final advance; every partition read runs
    /// lock-free in between. Pair with [`Consumer::commit_batch`] to also
    /// pay the commit lock once per batch. Within each partition,
    /// messages are in offset order.
    pub fn poll_batch(&self, max: usize) -> PolledBatch {
        self.poll_inner(max, usize::MAX)
    }

    /// [`Consumer::poll_batch`] with a byte budget: the batch's summed
    /// [`wire_cost`] stays within `max_bytes` (except for a single
    /// oversized head-of-line message — see the progress guarantee on
    /// `poll_inner`). The wire server polls through this so a reply
    /// `Batch` frame never encodes past `MAX_FRAME`, no matter the
    /// payload sizes behind the count cap.
    pub fn poll_batch_budgeted(&self, max: usize, max_bytes: usize) -> PolledBatch {
        self.poll_inner(max, max_bytes)
    }

    /// [`Consumer::poll_batch`] without materializing: returns shared
    /// slices into the partition logs. Same commit bookkeeping, same
    /// advance semantics — the messages are just never cloned. Callers
    /// that encode to the wire hand the result to
    /// [`encode_batch_ref`](crate::transport::frame::encode_batch_ref).
    pub fn poll_batch_shared(&self, max: usize) -> PolledBatchRef {
        self.poll_refs_inner(max, usize::MAX)
    }

    /// [`Consumer::poll_batch_budgeted`] in shared-slice form — the wire
    /// server's poll path: byte-budgeted against [`wire_cost`] and
    /// encoded straight from log memory.
    pub fn poll_batch_budgeted_shared(&self, max: usize, max_bytes: usize) -> PolledBatchRef {
        self.poll_refs_inner(max, max_bytes)
    }

    /// Commit `next` (the next offset to read) for `partition`.
    ///
    /// `next` is clamped to the partition's current end: committing past
    /// the log (possible only by seeding stale durable offsets into a
    /// fresh broker) would otherwise inflate the group's committed total
    /// and mask real lag on other partitions. Against a reset log, old
    /// offsets are meaningless — clamping re-delivers from what actually
    /// exists, which is the at-least-once answer.
    pub fn commit(&self, partition: usize, next: u64) {
        let next = next.min(self.topic.partitions[partition].end_offset());
        let delta = self.group.state.lock().unwrap().commit(partition, next);
        if delta > 0 {
            self.group.committed_total.fetch_add(delta, Ordering::Release);
            self.topic.checkpoint_commits(&self.group.name, &[(partition, next)]);
        }
    }

    /// Commit every watermark of `batch` under one coordinator lock.
    ///
    /// Returns `false` — and commits **nothing** — when the group has
    /// rebalanced since the batch was polled (the member is fenced, like
    /// a Kafka commit with a stale generation). The messages will be
    /// redelivered to their new owner from the last committed offset;
    /// callers that processed them simply see at-least-once duplicates.
    pub fn commit_batch(&self, batch: &PolledBatch) -> bool {
        if batch.next_offsets.is_empty() {
            return true;
        }
        let mut delta = 0;
        let mut moved: Vec<(usize, u64)> = Vec::new();
        {
            let mut g = self.group.state.lock().unwrap();
            if g.generation() != batch.generation {
                return false;
            }
            for &(p, next) in &batch.next_offsets {
                let d = g.commit(p, next);
                if d > 0 {
                    delta += d;
                    moved.push((p, g.committed(p)));
                }
            }
        }
        if delta > 0 {
            self.group.committed_total.fetch_add(delta, Ordering::Release);
            self.topic.checkpoint_commits(&self.group.name, &moved);
        }
        true
    }

    /// Commit everything consumed so far (positions → committed).
    pub fn commit_all(&self) {
        let mut delta = 0;
        let mut moved: Vec<(usize, u64)> = Vec::new();
        {
            let mut g = self.group.state.lock().unwrap();
            for p in g.assigned(self.member).to_vec() {
                let pos = g.position(p);
                let d = g.commit(p, pos);
                if d > 0 {
                    delta += d;
                    moved.push((p, pos));
                }
            }
        }
        if delta > 0 {
            self.group.committed_total.fetch_add(delta, Ordering::Release);
            self.topic.checkpoint_commits(&self.group.name, &moved);
        }
    }

    /// Leave the group gracefully.
    pub fn close(mut self) {
        self.leave();
    }

    fn leave(&mut self) {
        if self.open {
            self.open = false;
            self.group.state.lock().unwrap().leave(self.member);
        }
    }
}

impl Drop for Consumer {
    fn drop(&mut self) {
        self.leave();
    }
}

impl Default for Broker {
    fn default() -> Self {
        Broker {
            shards: std::array::from_fn(|_| RwLock::new(HashMap::new())),
            next_member: AtomicU64::new(1),
            storage: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker_with_topic(partitions: usize) -> Arc<Broker> {
        let b = Broker::new();
        b.create_topic("t", partitions);
        b
    }

    fn publish_n(b: &Arc<Broker>, n: usize) {
        let t = b.topic("t").unwrap();
        for i in 0..n {
            t.publish(Message::new(None, vec![i as u8], 0));
        }
    }

    #[test]
    fn publish_round_robin_spreads() {
        let b = broker_with_topic(3);
        publish_n(&b, 9);
        let t = b.topic("t").unwrap();
        assert_eq!(t.end_offsets(), vec![3, 3, 3]);
        assert_eq!(t.total_messages(), 9);
    }

    #[test]
    fn keyed_publish_stable_partition() {
        let b = broker_with_topic(4);
        let t = b.topic("t").unwrap();
        let (p1, _) = t.publish(Message::new(Some(77), vec![], 0));
        let (p2, _) = t.publish(Message::new(Some(77), vec![], 0));
        assert_eq!(p1, p2, "same key → same partition");
    }

    #[test]
    fn publish_batch_round_robin_spreads() {
        let b = broker_with_topic(3);
        let t = b.topic("t").unwrap();
        let placed = t.publish_batch((0..9).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(placed.len(), 9);
        assert_eq!(t.end_offsets(), vec![3, 3, 3]);
    }

    #[test]
    fn publish_batch_keyed_matches_single_publish() {
        let b = broker_with_topic(4);
        let t = b.topic("t").unwrap();
        let (p_single, _) = t.publish(Message::new(Some(42), vec![], 0));
        let placed = t.publish_batch(vec![
            Message::new(Some(42), vec![1], 0),
            Message::new(Some(42), vec![2], 0),
        ]);
        assert_eq!(placed[0].0, p_single, "batch and single publish agree on the partition");
        assert_eq!(placed[1].0, p_single);
        assert_eq!(placed[1].1, placed[0].1 + 1, "same-key offsets dense and ordered");
    }

    #[test]
    fn publish_batch_preserves_input_order_per_partition() {
        let b = broker_with_topic(2);
        let t = b.topic("t").unwrap();
        // Keys 0 and 1 hash to some partitions; interleave and check each
        // partition replays its subsequence in input order.
        let msgs: Vec<Message> =
            (0..20u8).map(|i| Message::new(Some((i % 2) as u64), vec![i], 0)).collect();
        let placed = t.publish_batch(msgs);
        for p in 0..2 {
            let replay = t.read(p, 0, 100);
            let expected: Vec<u8> = placed
                .iter()
                .enumerate()
                .filter(|(_, (part, _))| *part == p)
                .map(|(i, _)| i as u8)
                .collect();
            let got: Vec<u8> = replay.iter().map(|(_, m)| m.payload[0]).collect();
            assert_eq!(got, expected, "partition {p} order");
        }
    }

    #[test]
    fn publish_batch_single_partition_fast_paths() {
        // 1-partition topic: whole batch appends densely, in order.
        let b = broker_with_topic(1);
        let t = b.topic("t").unwrap();
        let placed = t.publish_batch((0..5u8).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(placed, (0..5).map(|i| (0, i)).collect::<Vec<_>>());
        assert_eq!(b.group_lag("t", "nobody"), 5, "fast path still counts published");

        // Multi-partition topic, single-key batch: one partition, dense
        // offsets, identical placement to per-message publishes.
        let b = broker_with_topic(4);
        let t = b.topic("t").unwrap();
        let (p_single, _) = t.publish(Message::new(Some(9), vec![], 0));
        let placed =
            t.publish_batch((0..6u8).map(|i| Message::new(Some(9), vec![i], 0)).collect());
        for (i, &(p, off)) in placed.iter().enumerate() {
            assert_eq!(p, p_single, "same key stays on its partition");
            assert_eq!(off, 1 + i as u64, "dense continuation after the single publish");
        }
        let replay: Vec<u8> =
            t.read(p_single, 1, 10).into_iter().map(|(_, m)| m.payload[0]).collect();
        assert_eq!(replay, (0..6u8).collect::<Vec<_>>(), "input order preserved");
    }

    #[test]
    fn publish_to_is_dense_and_counted() {
        let b = broker_with_topic(3);
        let t = b.topic("t").unwrap();
        let base = t.publish_to(1, (0..4u8).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(base, 0);
        let base2 = t.publish_to(1, vec![Message::new(None, vec![9], 0)]);
        assert_eq!(base2, 4, "offsets continue densely");
        assert_eq!(t.end_offsets(), vec![0, 5, 0], "only the addressed partition grows");
        assert_eq!(b.group_lag("t", "nobody"), 5, "explicit publishes count toward lag");
        assert_eq!(t.publish_to(0, vec![]), 0, "empty append returns the end offset");
    }

    #[test]
    fn partition_for_key_matches_broker_routing() {
        let b = broker_with_topic(4);
        let t = b.topic("t").unwrap();
        for key in [0u64, 1, 42, u64::MAX] {
            let (p, _) = t.publish(Message::new(Some(key), vec![], 0));
            assert_eq!(p, partition_for_key(key, 4), "client-side routing agrees");
        }
    }

    #[test]
    fn shared_poll_matches_owned_poll_step_for_step() {
        // Two identical brokers; one consumer polls owned batches, the
        // other shared slices. Every poll must agree on messages,
        // watermarks, and generation, and commits must land identically.
        let mk = || {
            let b = broker_with_topic(3);
            let t = b.topic("t").unwrap();
            t.publish_batch(
                (0..30u8)
                    .map(|i| {
                        Message::new(Some(i as u64 % 5), vec![i; (i as usize * 7) % 60 + 1], i as u64)
                    })
                    .collect(),
            );
            b
        };
        let (b1, b2) = (mk(), mk());
        let (c1, c2) = (b1.subscribe("t", "g"), b2.subscribe("t", "g"));
        loop {
            let owned = c1.poll_batch_budgeted(7, 400);
            let shared = c2.poll_batch_budgeted_shared(7, 400);
            assert_eq!(shared.generation, owned.generation);
            assert_eq!(shared.next_offsets, owned.next_offsets);
            assert_eq!(shared.len(), owned.len());
            let materialized = shared.to_polled_batch();
            assert_eq!(materialized.messages, owned.messages);
            assert!(c1.commit_batch(&owned));
            assert!(c2.commit_batch(&materialized));
            if owned.is_empty() {
                break;
            }
        }
        assert_eq!(b1.group_lag("t", "g"), 0);
        assert_eq!(b2.group_lag("t", "g"), 0);
    }

    #[test]
    fn shared_poll_first_message_beats_the_budget() {
        // The progress guarantee survives the refactor: a head-of-line
        // message larger than the whole budget is still delivered alone.
        let b = broker_with_topic(1);
        let t = b.topic("t").unwrap();
        t.publish(Message::new(None, vec![7; 1000], 0));
        t.publish(Message::new(None, vec![8; 1000], 0));
        let c = b.subscribe("t", "g");
        let batch = c.poll_batch_budgeted_shared(10, 64);
        assert_eq!(batch.len(), 1, "oversized head still delivered");
        assert_eq!(batch.next_offsets, vec![(0, 1)]);
        let second = c.poll_batch_budgeted_shared(10, 64);
        assert_eq!(second.len(), 1);
        assert_eq!(second.next_offsets, vec![(0, 2)]);
    }

    #[test]
    fn budgeted_poll_trims_to_bytes_and_redelivers_the_rest() {
        let b = broker_with_topic(1);
        let t = b.topic("t").unwrap();
        t.publish_batch((0..6u8).map(|i| Message::new(None, vec![i; 100], 0)).collect());
        let c = b.subscribe("t", "g");
        // Budget fits two 132-byte messages, not three.
        let batch = c.poll_batch_budgeted(100, 300);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.next_offsets, vec![(0, 2)], "watermark covers only the kept prefix");
        // The trimmed messages come back on the next poll — nothing skipped.
        let rest = c.poll_batch_budgeted(100, usize::MAX);
        let offsets: Vec<u64> = rest.messages.iter().map(|m| m.offset).collect();
        assert_eq!(offsets, vec![2, 3, 4, 5]);
    }

    #[test]
    fn budgeted_poll_always_delivers_an_oversized_head_message() {
        let b = broker_with_topic(1);
        let t = b.topic("t").unwrap();
        t.publish(Message::new(None, vec![7; 10_000], 0));
        t.publish(Message::new(None, vec![8; 10_000], 0));
        let c = b.subscribe("t", "g");
        let batch = c.poll_batch_budgeted(100, 64);
        assert_eq!(batch.len(), 1, "head-of-line message delivered despite the budget");
        assert_eq!(batch.messages[0].offset, 0);
    }

    #[test]
    fn sharded_registry_finds_every_topic() {
        let b = Broker::new();
        // Enough names to land on many different shards.
        for i in 0..50usize {
            b.create_topic(&format!("topic-{i}"), 1 + i % 4);
        }
        for i in 0..50usize {
            let t = b.topic(&format!("topic-{i}")).expect("topic resolvable");
            assert_eq!(t.partition_count(), 1 + i % 4);
        }
        assert!(b.topic("missing").is_none());
        assert_eq!(b.topic_names().len(), 50);
    }

    #[test]
    fn single_consumer_sees_everything() {
        let b = broker_with_topic(3);
        publish_n(&b, 30);
        let c = b.subscribe("t", "g");
        let mut got = 0;
        loop {
            let batch = c.poll(7);
            if batch.is_empty() {
                break;
            }
            got += batch.len();
        }
        assert_eq!(got, 30);
    }

    #[test]
    fn poll_rotates_start_partition() {
        let b = broker_with_topic(3);
        publish_n(&b, 30);
        let c = b.subscribe("t", "g");
        // With max=1 the old assignment-order walk would drain partition 0
        // completely before ever visiting 1 and 2; rotation must touch all
        // three within the first three polls.
        let mut first_three = std::collections::BTreeSet::new();
        for _ in 0..3 {
            let got = c.poll(1);
            assert_eq!(got.len(), 1);
            first_three.insert(got[0].partition);
        }
        assert_eq!(first_three.len(), 3, "each poll starts at the next partition");
    }

    #[test]
    fn poll_batch_watermarks_cover_messages() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let c = b.subscribe("t", "g");
        let batch = c.poll_batch(10);
        assert_eq!(batch.len(), 10);
        let mut next = batch.next_offsets.clone();
        next.sort_unstable();
        assert_eq!(next, vec![(0, 5), (1, 5)]);
        assert!(c.commit_batch(&batch), "same generation: commit applies");
        assert_eq!(b.committed("t", "g", 0), 5);
        assert_eq!(b.committed("t", "g", 1), 5);
        assert_eq!(b.group_lag("t", "g"), 0);
    }

    #[test]
    fn commit_batch_fenced_after_rebalance() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let c1 = b.subscribe("t", "g");
        let batch = c1.poll_batch(10);
        assert_eq!(batch.len(), 10);
        let _c2 = b.subscribe("t", "g"); // rebalance bumps the generation
        assert!(!c1.commit_batch(&batch), "stale-generation commit is fenced");
        assert_eq!(b.committed("t", "g", 0), 0);
        assert_eq!(b.committed("t", "g", 1), 0);
        assert_eq!(b.group_lag("t", "g"), 10, "everything will be redelivered");
    }

    #[test]
    fn empty_poll_batch_commits_trivially() {
        let b = broker_with_topic(1);
        let c = b.subscribe("t", "g");
        let batch = c.poll_batch(5);
        assert!(batch.is_empty());
        assert!(c.commit_batch(&batch));
    }

    #[test]
    fn group_splits_partitions_exclusively() {
        let b = broker_with_topic(3);
        publish_n(&b, 30);
        let c1 = b.subscribe("t", "g");
        let c2 = b.subscribe("t", "g");
        let mut parts = c1.assignment();
        parts.extend(c2.assignment());
        parts.sort_unstable();
        assert_eq!(parts, vec![0, 1, 2], "all partitions covered exactly once");
    }

    #[test]
    fn extra_consumers_idle() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let consumers: Vec<Consumer> = (0..5).map(|_| b.subscribe("t", "g")).collect();
        let active = consumers.iter().filter(|c| !c.assignment().is_empty()).count();
        assert_eq!(active, 2, "Liquid's cap: active members = partitions");
    }

    #[test]
    fn crash_without_commit_redelivers() {
        let b = broker_with_topic(1);
        publish_n(&b, 10);
        let c1 = b.subscribe("t", "g");
        let batch = c1.poll(5);
        assert_eq!(batch.len(), 5);
        drop(c1); // crash: no commit
        let c2 = b.subscribe("t", "g");
        let batch = c2.poll(10);
        assert_eq!(batch.len(), 10, "uncommitted messages redelivered");
        assert_eq!(batch[0].offset, 0);
    }

    #[test]
    fn commit_then_crash_resumes_at_commit() {
        let b = broker_with_topic(1);
        publish_n(&b, 10);
        let c1 = b.subscribe("t", "g");
        let batch = c1.poll(4);
        assert_eq!(batch.len(), 4);
        c1.commit(0, 4);
        drop(c1);
        let c2 = b.subscribe("t", "g");
        let batch = c2.poll(10);
        assert_eq!(batch.len(), 6);
        assert_eq!(batch[0].offset, 4);
    }

    #[test]
    fn commit_all_commits_positions() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let c = b.subscribe("t", "g");
        let n = c.poll(10).len();
        assert_eq!(n, 10);
        c.commit_all();
        assert_eq!(b.committed("t", "g", 0), 5);
        assert_eq!(b.committed("t", "g", 1), 5);
        assert_eq!(b.group_lag("t", "g"), 0);
    }

    #[test]
    fn publish_to_at_skipped_messages_never_inflate_lag() {
        let b = broker_with_topic(1);
        let t = b.topic("t").unwrap();
        let batch = |base: u64, n: u64| -> Vec<Message> {
            (base..base + n).map(|o| Message::new(None, vec![o as u8], 0)).collect()
        };
        assert_eq!(t.publish_to_at(0, 0, batch(0, 3)), 3);
        // A duplicate apply and a gapped apply append nothing — and must
        // leave the published count (= lag for a fresh group) untouched.
        assert_eq!(t.publish_to_at(0, 0, batch(0, 3)), 3);
        assert_eq!(t.publish_to_at(0, 9, batch(9, 2)), 3);
        // Overlap counts only the unseen suffix.
        assert_eq!(t.publish_to_at(0, 1, batch(1, 4)), 5);
        assert_eq!(t.total_messages(), 5);
        assert_eq!(b.group_lag("t", "nobody"), 5, "lag == messages actually appended");
    }

    #[test]
    fn group_lag_counts_uncommitted() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        assert_eq!(b.group_lag("t", "g"), 10, "no group yet: everything is lag");
        let c = b.subscribe("t", "g");
        c.poll(10);
        assert_eq!(b.group_lag("t", "g"), 10, "polled but uncommitted still lags");
        c.commit_all();
        assert_eq!(b.group_lag("t", "g"), 0);
    }

    #[test]
    fn total_lag_sums_topics_and_groups() {
        let b = Broker::new();
        b.create_topic("a", 2);
        b.create_topic("b", 1);
        let ta = b.topic("a").unwrap();
        let tb = b.topic("b").unwrap();
        ta.publish_batch((0..6u8).map(|i| Message::new(None, vec![i], 0)).collect());
        tb.publish_batch((0..4u8).map(|i| Message::new(None, vec![i], 0)).collect());
        // No groups anywhere: nobody is behind.
        assert_eq!(b.total_lag(), 0);
        let ca = b.subscribe("a", "g1");
        let ca2 = b.subscribe("a", "g2");
        let cb = b.subscribe("b", "g1");
        assert_eq!(b.total_lag(), 6 + 6 + 4, "each group lags independently");
        let batch = ca.poll_batch(10);
        assert!(ca.commit_batch(&batch));
        assert_eq!(b.total_lag(), 6 + 4);
        let batch = ca2.poll_batch(10);
        assert!(ca2.commit_batch(&batch));
        let batch = cb.poll_batch(10);
        assert!(cb.commit_batch(&batch));
        assert_eq!(b.total_lag(), 0);
    }

    #[test]
    fn independent_groups_independent_progress() {
        let b = broker_with_topic(1);
        publish_n(&b, 6);
        let ca = b.subscribe("t", "ga");
        let cb = b.subscribe("t", "gb");
        assert_eq!(ca.poll(10).len(), 6);
        assert_eq!(cb.poll(10).len(), 6, "each group reads the full log");
    }

    #[test]
    #[should_panic(expected = "different partition count")]
    fn topic_recreation_with_mismatch_panics() {
        let b = broker_with_topic(3);
        b.create_topic("t", 4);
    }

    mod durable {
        use super::*;
        use crate::messaging::storage::{FsyncPolicy, MemStorage, StorageConfig};

        #[test]
        fn kill_and_reopen_serves_acked_messages_and_resumes_commits() {
            let storage = MemStorage::new(StorageConfig::default());
            {
                let b = Broker::with_storage(storage.clone()).unwrap();
                b.create_topic("t", 2);
                let t = b.topic("t").unwrap();
                t.publish_batch((0..10u8).map(|i| Message::new(None, vec![i], 0)).collect());
                let c = b.subscribe("t", "g");
                let batch = c.poll_batch(6);
                assert_eq!(batch.len(), 6);
                assert!(c.commit_batch(&batch));
            }
            storage.kill();
            let b = Broker::with_storage(storage).unwrap();
            let t = b.topic("t").expect("topic recovered from the manifest");
            assert_eq!(t.total_messages(), 10, "every acked message survived");
            assert_eq!(b.group_lag("t", "g"), 4, "group resumes at its checkpoint");
            let c = b.subscribe("t", "g");
            let mut got = 0;
            loop {
                let batch = c.poll_batch(8);
                if batch.is_empty() {
                    break;
                }
                got += batch.len();
                assert!(c.commit_batch(&batch));
            }
            assert_eq!(got, 4, "only the uncommitted suffix is redelivered");
            assert_eq!(b.total_lag(), 0);
        }

        #[test]
        fn power_loss_with_fsync_off_loses_only_unsynced_tail() {
            let cfg = StorageConfig { fsync: FsyncPolicy::Off, ..StorageConfig::default() };
            let storage = MemStorage::new(cfg);
            {
                let b = Broker::with_storage(storage.clone()).unwrap();
                let t = b.create_topic("t", 1);
                t.publish_batch((0..5u8).map(|i| Message::new(None, vec![i], 0)).collect());
                storage.sync();
                t.publish_batch((5..9u8).map(|i| Message::new(None, vec![i], 0)).collect());
            }
            storage.crash();
            let b = Broker::with_storage(storage).unwrap();
            let t = b.topic("t").unwrap();
            assert_eq!(t.total_messages(), 5, "synced prefix survives; offsets stay dense");
            let c = b.subscribe("t", "g");
            let msgs = c.poll(10);
            let payloads: Vec<u8> = msgs.iter().map(|m| m.message.payload[0]).collect();
            assert_eq!(payloads, vec![0, 1, 2, 3, 4], "no gaps, prefix order intact");
        }

        #[test]
        fn checkpoint_clamped_to_recovered_log_end() {
            // Commits synced, appends not: after power loss the checkpoint
            // can point past the recovered log. It must clamp, not mask lag.
            let cfg = StorageConfig { fsync: FsyncPolicy::Off, ..StorageConfig::default() };
            let storage = MemStorage::new(cfg);
            {
                let b = Broker::with_storage(storage.clone()).unwrap();
                let t = b.create_topic("t", 1);
                t.publish_batch((0..3u8).map(|i| Message::new(None, vec![i], 0)).collect());
                storage.sync(); // 3 messages durable
                t.publish_batch((3..8u8).map(|i| Message::new(None, vec![i], 0)).collect());
                let c = b.subscribe("t", "g");
                let batch = c.poll_batch(8);
                assert_eq!(batch.len(), 8);
                assert!(c.commit_batch(&batch));
                // Sync ONLY the checkpoint ahead of the appends.
                storage.checkpoint("t", "g", &[(0, 8)]);
            }
            // Promote commits but not the appends: model a checkpoint file
            // that survived while tail appends did not.
            storage.sync_commits_only_for_test();
            storage.crash();
            let b = Broker::with_storage(storage).unwrap();
            assert_eq!(b.topic("t").unwrap().total_messages(), 3);
            assert_eq!(b.committed("t", "g", 0), 3, "commit clamped to the log end");
            assert_eq!(b.group_lag("t", "g"), 0);
        }

        #[test]
        fn fresh_durable_broker_behaves_like_in_memory() {
            let storage = MemStorage::new(StorageConfig::default());
            let b = Broker::with_storage(storage).unwrap();
            b.create_topic("t", 3);
            let t = b.topic("t").unwrap();
            for i in 0..30u8 {
                t.publish(Message::new(None, vec![i], 0));
            }
            let c = b.subscribe("t", "g");
            let mut got = 0;
            loop {
                let batch = c.poll(7);
                if batch.is_empty() {
                    break;
                }
                got += batch.len();
            }
            assert_eq!(got, 30);
        }

        #[test]
        fn durable_topic_partition_mismatch_is_error_not_silent() {
            let storage = MemStorage::new(StorageConfig::default());
            {
                let b = Broker::with_storage(storage.clone()).unwrap();
                b.create_topic("t", 2);
            }
            storage.kill();
            let b = Broker::with_storage(storage).unwrap();
            // Recovery already re-created "t" with 2 partitions.
            assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                b.create_topic("t", 3);
            }))
            .is_err());
        }
    }
}
