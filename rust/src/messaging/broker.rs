//! The broker: topics + consumer-group coordinator + consumer handles.

use super::group::{GroupState, MemberId};
use super::message::{Message, OffsetMessage};
use super::partition::PartitionLog;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One topic: partition logs plus per-group coordination state.
pub struct Topic {
    pub name: String,
    partitions: Vec<PartitionLog>,
    groups: Mutex<HashMap<String, GroupState>>,
    /// Round-robin cursor for keyless produces.
    rr: AtomicUsize,
}

impl Topic {
    fn new(name: &str, partitions: usize) -> Self {
        assert!(partitions >= 1, "topic needs >= 1 partition");
        Topic {
            name: name.to_string(),
            partitions: (0..partitions).map(|_| PartitionLog::new()).collect(),
            groups: Mutex::new(HashMap::new()),
            rr: AtomicUsize::new(0),
        }
    }

    pub fn partition_count(&self) -> usize {
        self.partitions.len()
    }

    /// Total messages across partitions.
    pub fn end_offsets(&self) -> Vec<u64> {
        self.partitions.iter().map(|p| p.end_offset()).collect()
    }

    pub fn total_messages(&self) -> u64 {
        self.end_offsets().iter().sum()
    }

    /// Publish, choosing the partition from the key hash (or round-robin).
    pub fn publish(&self, msg: Message) -> (usize, u64) {
        let p = match msg.key {
            Some(k) => (hash64(k) % self.partitions.len() as u64) as usize,
            None => self.rr.fetch_add(1, Ordering::Relaxed) % self.partitions.len(),
        };
        let off = self.partitions[p].append(msg);
        (p, off)
    }

    /// Read a raw window from one partition (offset-addressed, group-free).
    pub fn read(&self, partition: usize, from: u64, max: usize) -> Vec<(u64, Message)> {
        self.partitions[partition].read(from, max)
    }
}

#[inline]
fn hash64(mut x: u64) -> u64 {
    // SplitMix64 finalizer as a cheap, well-mixed hash.
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// The in-process broker (the messaging layer).
pub struct Broker {
    topics: RwLock<HashMap<String, Arc<Topic>>>,
    next_member: AtomicU64,
}

impl Broker {
    pub fn new() -> Arc<Self> {
        Arc::new(Broker { topics: RwLock::new(HashMap::new()), next_member: AtomicU64::new(1) })
    }

    /// Create a topic (idempotent; partition count must match an existing
    /// topic or the call panics — config error).
    pub fn create_topic(self: &Arc<Self>, name: &str, partitions: usize) -> Arc<Topic> {
        let mut t = self.topics.write().unwrap();
        let topic = t
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Topic::new(name, partitions)))
            .clone();
        assert_eq!(
            topic.partition_count(),
            partitions,
            "topic '{name}' exists with different partition count"
        );
        topic
    }

    pub fn topic(&self, name: &str) -> Option<Arc<Topic>> {
        self.topics.read().unwrap().get(name).cloned()
    }

    fn expect_topic(&self, name: &str) -> Arc<Topic> {
        self.topic(name).unwrap_or_else(|| panic!("unknown topic '{name}'"))
    }

    /// Join `group` on `topic`, returning a consumer handle. The handle
    /// leaves the group on [`Consumer::close`] or drop (crash semantics:
    /// dropping without commit rewinds the group to the committed offsets).
    pub fn subscribe(self: &Arc<Self>, topic: &str, group: &str) -> Consumer {
        let t = self.expect_topic(topic);
        let member = self.next_member.fetch_add(1, Ordering::Relaxed);
        {
            let mut groups = t.groups.lock().unwrap();
            let g = groups
                .entry(group.to_string())
                .or_insert_with(|| GroupState::new(t.partition_count()));
            g.join(member);
        }
        Consumer { topic: t, group: group.to_string(), member, open: true }
    }

    /// Number of members currently in `group` on `topic`.
    pub fn group_members(&self, topic: &str, group: &str) -> usize {
        let t = self.expect_topic(topic);
        let groups = t.groups.lock().unwrap();
        groups.get(group).map(|g| g.member_count()).unwrap_or(0)
    }

    /// Committed offset for `(topic, group, partition)`.
    pub fn committed(&self, topic: &str, group: &str, partition: usize) -> u64 {
        let t = self.expect_topic(topic);
        let groups = t.groups.lock().unwrap();
        groups.get(group).map(|g| g.committed(partition)).unwrap_or(0)
    }

    /// Sum of unconsumed (past committed) messages for a group — the lag
    /// the elastic-worker service watches.
    pub fn group_lag(&self, topic: &str, group: &str) -> u64 {
        let t = self.expect_topic(topic);
        let ends = t.end_offsets();
        let groups = t.groups.lock().unwrap();
        match groups.get(group) {
            None => ends.iter().sum(),
            Some(g) => ends
                .iter()
                .enumerate()
                .map(|(p, &e)| e.saturating_sub(g.committed(p)))
                .sum(),
        }
    }
}

/// A consumer-group member handle.
///
/// `poll` reads batches from the member's assigned partitions and advances
/// the group's in-memory positions; `commit` durably records progress so a
/// restarted member resumes there. Dropping without closing mimics a crash.
pub struct Consumer {
    topic: Arc<Topic>,
    group: String,
    member: MemberId,
    open: bool,
}

impl Consumer {
    pub fn member_id(&self) -> MemberId {
        self.member
    }

    pub fn topic_name(&self) -> &str {
        &self.topic.name
    }

    /// Partitions this member currently owns.
    pub fn assignment(&self) -> Vec<usize> {
        let groups = self.topic.groups.lock().unwrap();
        groups.get(&self.group).map(|g| g.assigned(self.member).to_vec()).unwrap_or_default()
    }

    /// Poll up to `max` messages across owned partitions (round-robin over
    /// partitions, batch per partition). Non-blocking: may return empty.
    pub fn poll(&self, max: usize) -> Vec<OffsetMessage> {
        let mut out = Vec::new();
        let mut groups = self.topic.groups.lock().unwrap();
        let g = match groups.get_mut(&self.group) {
            Some(g) => g,
            None => return out,
        };
        let parts = g.assigned(self.member).to_vec();
        for p in parts {
            if out.len() >= max {
                break;
            }
            let from = g.position(p);
            let batch = self.topic.partitions[p].read(from, max - out.len());
            if let Some((last, _)) = batch.last() {
                g.advance(p, last + 1);
            }
            out.extend(batch.into_iter().map(|(offset, message)| OffsetMessage {
                partition: p,
                offset,
                message,
            }));
        }
        out
    }

    /// Commit `next` (the next offset to read) for `partition`.
    pub fn commit(&self, partition: usize, next: u64) {
        let mut groups = self.topic.groups.lock().unwrap();
        if let Some(g) = groups.get_mut(&self.group) {
            g.commit(partition, next);
        }
    }

    /// Commit everything consumed so far (positions → committed).
    pub fn commit_all(&self) {
        let mut groups = self.topic.groups.lock().unwrap();
        if let Some(g) = groups.get_mut(&self.group) {
            for p in g.assigned(self.member).to_vec() {
                let pos = g.position(p);
                g.commit(p, pos);
            }
        }
    }

    /// Leave the group gracefully.
    pub fn close(mut self) {
        self.leave();
    }

    fn leave(&mut self) {
        if self.open {
            self.open = false;
            let mut groups = self.topic.groups.lock().unwrap();
            if let Some(g) = groups.get_mut(&self.group) {
                g.leave(self.member);
            }
        }
    }
}

impl Drop for Consumer {
    fn drop(&mut self) {
        self.leave();
    }
}

impl Default for Broker {
    fn default() -> Self {
        Broker { topics: RwLock::new(HashMap::new()), next_member: AtomicU64::new(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker_with_topic(partitions: usize) -> Arc<Broker> {
        let b = Broker::new();
        b.create_topic("t", partitions);
        b
    }

    fn publish_n(b: &Arc<Broker>, n: usize) {
        let t = b.topic("t").unwrap();
        for i in 0..n {
            t.publish(Message::new(None, vec![i as u8], 0));
        }
    }

    #[test]
    fn publish_round_robin_spreads() {
        let b = broker_with_topic(3);
        publish_n(&b, 9);
        let t = b.topic("t").unwrap();
        assert_eq!(t.end_offsets(), vec![3, 3, 3]);
        assert_eq!(t.total_messages(), 9);
    }

    #[test]
    fn keyed_publish_stable_partition() {
        let b = broker_with_topic(4);
        let t = b.topic("t").unwrap();
        let (p1, _) = t.publish(Message::new(Some(77), vec![], 0));
        let (p2, _) = t.publish(Message::new(Some(77), vec![], 0));
        assert_eq!(p1, p2, "same key → same partition");
    }

    #[test]
    fn single_consumer_sees_everything() {
        let b = broker_with_topic(3);
        publish_n(&b, 30);
        let c = b.subscribe("t", "g");
        let mut got = 0;
        loop {
            let batch = c.poll(7);
            if batch.is_empty() {
                break;
            }
            got += batch.len();
        }
        assert_eq!(got, 30);
    }

    #[test]
    fn group_splits_partitions_exclusively() {
        let b = broker_with_topic(3);
        publish_n(&b, 30);
        let c1 = b.subscribe("t", "g");
        let c2 = b.subscribe("t", "g");
        let mut parts = c1.assignment();
        parts.extend(c2.assignment());
        parts.sort_unstable();
        assert_eq!(parts, vec![0, 1, 2], "all partitions covered exactly once");
    }

    #[test]
    fn extra_consumers_idle() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let consumers: Vec<Consumer> = (0..5).map(|_| b.subscribe("t", "g")).collect();
        let active = consumers.iter().filter(|c| !c.assignment().is_empty()).count();
        assert_eq!(active, 2, "Liquid's cap: active members = partitions");
    }

    #[test]
    fn crash_without_commit_redelivers() {
        let b = broker_with_topic(1);
        publish_n(&b, 10);
        let c1 = b.subscribe("t", "g");
        let batch = c1.poll(5);
        assert_eq!(batch.len(), 5);
        drop(c1); // crash: no commit
        let c2 = b.subscribe("t", "g");
        let batch = c2.poll(10);
        assert_eq!(batch.len(), 10, "uncommitted messages redelivered");
        assert_eq!(batch[0].offset, 0);
    }

    #[test]
    fn commit_then_crash_resumes_at_commit() {
        let b = broker_with_topic(1);
        publish_n(&b, 10);
        let c1 = b.subscribe("t", "g");
        let batch = c1.poll(4);
        assert_eq!(batch.len(), 4);
        c1.commit(0, 4);
        drop(c1);
        let c2 = b.subscribe("t", "g");
        let batch = c2.poll(10);
        assert_eq!(batch.len(), 6);
        assert_eq!(batch[0].offset, 4);
    }

    #[test]
    fn commit_all_commits_positions() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        let c = b.subscribe("t", "g");
        let n = c.poll(10).len();
        assert_eq!(n, 10);
        c.commit_all();
        assert_eq!(b.committed("t", "g", 0), 5);
        assert_eq!(b.committed("t", "g", 1), 5);
        assert_eq!(b.group_lag("t", "g"), 0);
    }

    #[test]
    fn group_lag_counts_uncommitted() {
        let b = broker_with_topic(2);
        publish_n(&b, 10);
        assert_eq!(b.group_lag("t", "g"), 10, "no group yet: everything is lag");
        let c = b.subscribe("t", "g");
        c.poll(10);
        assert_eq!(b.group_lag("t", "g"), 10, "polled but uncommitted still lags");
        c.commit_all();
        assert_eq!(b.group_lag("t", "g"), 0);
    }

    #[test]
    fn independent_groups_independent_progress() {
        let b = broker_with_topic(1);
        publish_n(&b, 6);
        let ca = b.subscribe("t", "ga");
        let cb = b.subscribe("t", "gb");
        assert_eq!(ca.poll(10).len(), 6);
        assert_eq!(cb.poll(10).len(), 6, "each group reads the full log");
    }

    #[test]
    #[should_panic(expected = "different partition count")]
    fn topic_recreation_with_mismatch_panics() {
        let b = broker_with_topic(3);
        b.create_topic("t", 4);
    }
}
