//! Messaging layer — an in-process message broker with Apache Kafka's
//! semantics (the paper's messaging layer, §3.2.1).
//!
//! What matters for the paper's argument is reproduced exactly:
//!
//! - topics are split into **partitions**, each an append-only offset-indexed
//!   log ([`partition`]);
//! - producers publish to a partition chosen by key hash or round-robin
//!   ([`producer`]);
//! - consumers belong to **consumer groups**; within a group each partition
//!   is assigned to *at most one* member ([`group`]), so a group can have at
//!   most `partitions` active members — the precise limitation (Fig. 2 of
//!   the paper) that caps Liquid's tasks-per-job and that the virtual
//!   messaging layer lifts;
//! - consumption is batch **polling** with positions and explicit offset
//!   **commits**, giving at-least-once redelivery after a member failure.
//!
//! The broker is a plain in-process object behind `Arc`; all state is
//! internally synchronized, so producers/consumers can live on any thread
//! (or simulated cluster node).

pub mod broker;
pub mod group;
pub mod message;
pub mod partition;
pub mod producer;

pub use broker::Broker;
pub use group::MemberId;
pub use message::Message;
pub use producer::Producer;

pub use broker::Consumer;
