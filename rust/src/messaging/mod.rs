//! Messaging layer — an in-process message broker with Apache Kafka's
//! semantics (the paper's messaging layer, §3.2.1).
//!
//! What matters for the paper's argument is reproduced exactly:
//!
//! - topics are split into **partitions**, each an append-only offset-indexed
//!   log ([`partition`]);
//! - producers publish to a partition chosen by key hash or round-robin
//!   ([`producer`]);
//! - consumers belong to **consumer groups**; within a group each partition
//!   is assigned to *at most one* member ([`group`]), so a group can have at
//!   most `partitions` active members — the precise limitation (Fig. 2 of
//!   the paper) that caps Liquid's tasks-per-job and that the virtual
//!   messaging layer lifts;
//! - consumption is batch **polling** with positions and explicit offset
//!   **commits**, giving at-least-once redelivery after a member failure.
//!
//! # Coordinator/data-plane lock split
//!
//! The data plane and group coordination are synchronized independently:
//!
//! - partition logs are **segmented and lock-free to read** — appends
//!   serialize on a small writer mutex and publish via an atomic tail
//!   counter; reads acquire-load the tail and walk the committed prefix
//!   with no lock held ([`partition::PartitionLog`]);
//! - each consumer group has its **own coordinator mutex** (the topic
//!   keeps a registry of `Arc`-shared per-group locks), so groups on one
//!   topic never serialize on each other, and `poll`/`poll_batch` hold
//!   the group lock only to snapshot and to advance — the partition reads
//!   in between run unlocked;
//! - lag probes ([`Broker::group_lag`], [`Broker::total_lag`]) read
//!   published/committed **atomic counters** instead of walking the
//!   registry under locks — O(groups) atomic loads per probe.
//!
//! # Batch-first API
//!
//! Every data-plane operation has a batched form that amortizes
//! coordination costs over the `n`-message cycle of Eq. 1
//! (`T = n·t_c + i·t_p`):
//!
//! | per-message                  | batched                         | cost paid once per batch |
//! |------------------------------|---------------------------------|--------------------------|
//! | [`broker::Topic::publish`]   | [`broker::Topic::publish_batch`]| partition routing + tail publish (per touched partition) |
//! | [`Producer::send`]           | [`Producer::send_batch`]        | clock stamp + the above  |
//! | [`broker::Consumer::poll`]   | [`broker::Consumer::poll_batch`]| group-coordinator snapshot/advance |
//! | [`broker::Consumer::commit`] | [`broker::Consumer::commit_batch`]| group-coordinator lock |
//!
//! **Ordering.** A batch publish is equivalent to publishing its messages
//! one by one: keyed messages land on their key's partition and every
//! partition preserves batch input order, so per-key ordering holds within
//! and across batches. `poll_batch` returns each partition's messages in
//! offset order.
//!
//! **Commit semantics.** [`broker::PolledBatch`] carries per-partition
//! `next_offsets` watermarks plus the group's rebalance `generation` at
//! poll time. [`broker::Consumer::commit_batch`] applies all watermarks
//! atomically under one coordinator lock *iff* the generation still
//! matches; a commit from before a rebalance is fenced (returns `false`,
//! commits nothing), so ownership hand-offs always resume from the last
//! committed offset and delivery stays at-least-once.
//!
//! The broker is a plain in-process object behind `Arc`; all state is
//! internally synchronized (the topic registry itself is sharded — see
//! [`broker::Broker`]), so producers/consumers can live on any thread
//! (or simulated cluster node). `cargo bench --bench broker_contention`
//! sweeps N producers × M consumer groups to show the multi-threaded
//! scaling the lock split buys.
//!
//! # Durability
//!
//! A broker opened with [`Broker::with_storage`] writes every partition
//! through a [`storage`] backend: append-only segment files sealed with
//! the wire protocol's CRC-32, a compacted committed-offset checkpoint,
//! and a pluggable fsync policy ([`storage::FsyncPolicy`]). On startup
//! the backend scans its segments, truncates torn tails at the last
//! valid CRC boundary, and the broker resumes topics and group offsets
//! where the last acked state left them — acknowledged messages survive
//! `kill -9` under every policy, and redelivery stays bounded by the
//! checkpoint cadence. `Broker::new` remains purely in-memory.
//!
//! # The client seam
//!
//! Layers above the messaging layer hold the broker through
//! [`client::BrokerClient`] / [`client::ConsumerClient`] — a narrow,
//! batch-first trait pair that `Broker`/`Consumer` implement directly
//! and that [`transport::RemoteBroker`](crate::transport::RemoteBroker)
//! implements over a wire connection, so the same pipeline runs against
//! a broker in this process or on another node.

// The zero-copy wire path exists to kill redundant clones on the
// hot path; keep this layer honest about new ones.
#![deny(clippy::redundant_clone)]

pub mod broker;
pub mod client;
pub mod group;
pub mod message;
pub mod partition;
pub mod producer;
pub mod storage;

pub use broker::Broker;
pub use client::{BrokerClient, ConsumerClient, SharedBrokerClient};
pub use group::MemberId;
pub use message::Message;
pub use producer::Producer;
pub use storage::{DiskStorage, FsyncPolicy, MemStorage, Storage, StorageConfig, StorageError};

pub use broker::{Consumer, PolledBatch, PolledBatchRef};
pub use partition::{BatchRef, MessageSlice};
