//! Messages: immutable, cheaply clonable payloads.

use std::sync::Arc;

/// A message as stored in a partition log.
///
/// The payload is `Arc<[u8]>` so that fan-out through the virtual messaging
/// layer and task pools never copies message bodies — only bumps a
/// refcount. `produced_at_ms` is the broker-ingest timestamp (millis on the
/// experiment clock) used by the metrics layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Message {
    /// Partitioning key (hashed to choose a partition when present).
    pub key: Option<u64>,
    pub payload: Arc<[u8]>,
    /// Millis since the experiment clock epoch at produce time.
    pub produced_at_ms: u64,
}

impl Message {
    pub fn new(key: Option<u64>, payload: Vec<u8>, produced_at_ms: u64) -> Self {
        Message { key, payload: payload.into(), produced_at_ms }
    }

    /// Build from an already-shared payload without copying it (the wire
    /// decode path hands its `Arc` straight in here).
    pub fn with_payload(key: Option<u64>, payload: Arc<[u8]>, produced_at_ms: u64) -> Self {
        Message { key, payload, produced_at_ms }
    }

    /// Convenience for tests and examples.
    pub fn from_str(s: &str) -> Self {
        Message::new(None, s.as_bytes().to_vec(), 0)
    }

    pub fn payload_str(&self) -> Option<&str> {
        std::str::from_utf8(&self.payload).ok()
    }

    pub fn len(&self) -> usize {
        self.payload.len()
    }

    pub fn is_empty(&self) -> bool {
        self.payload.is_empty()
    }
}

/// A message paired with its position in a partition log.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OffsetMessage {
    pub partition: usize,
    pub offset: u64,
    pub message: Message,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_payload() {
        let m = Message::new(Some(1), vec![1, 2, 3], 5);
        let c = m.clone();
        assert!(Arc::ptr_eq(&m.payload, &c.payload));
        assert_eq!(c.key, Some(1));
        assert_eq!(c.produced_at_ms, 5);
    }

    #[test]
    fn str_round_trip() {
        let m = Message::from_str("hello");
        assert_eq!(m.payload_str(), Some("hello"));
        assert_eq!(m.len(), 5);
        assert!(!m.is_empty());
    }
}
