//! Producer handle: publishes messages stamped with the experiment clock.

use super::broker::{Broker, Topic};
use super::message::Message;
use crate::util::clock::SharedClock;
use std::sync::Arc;

/// Publishes to one topic. Cheap to clone/create; holds the topic `Arc`
/// directly so the hot path skips the broker's topic map.
pub struct Producer {
    topic: Arc<Topic>,
    clock: SharedClock,
}

impl Producer {
    pub fn new(broker: &Arc<Broker>, topic: &str, clock: SharedClock) -> Self {
        let topic = broker.topic(topic).unwrap_or_else(|| panic!("unknown topic '{topic}'"));
        Producer { topic, clock }
    }

    /// Publish a payload; returns `(partition, offset)`.
    pub fn send(&self, key: Option<u64>, payload: Vec<u8>) -> (usize, u64) {
        self.topic.publish(Message::new(key, payload, self.clock.now_millis()))
    }

    /// Publish a pre-built message, restamping its produce time.
    pub fn send_message(&self, mut msg: Message) -> (usize, u64) {
        msg.produced_at_ms = self.clock.now_millis();
        self.topic.publish(msg)
    }

    pub fn topic_name(&self) -> &str {
        &self.topic.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::time::Duration;

    #[test]
    fn stamps_produce_time() {
        let b = Broker::new();
        b.create_topic("t", 2);
        let clock = Arc::new(ManualClock::new());
        let p = Producer::new(&b, "t", clock.clone());
        clock.advance(Duration::from_millis(123));
        p.send(None, vec![1]);
        let c = b.subscribe("t", "g");
        let got = c.poll(1);
        assert_eq!(got[0].message.produced_at_ms, 123);
    }

    #[test]
    #[should_panic(expected = "unknown topic")]
    fn unknown_topic_panics() {
        let b = Broker::new();
        let clock: SharedClock = Arc::new(ManualClock::new());
        let _ = Producer::new(&b, "missing", clock);
    }
}
