//! Producer handle: publishes messages stamped with the experiment clock.

use super::broker::Broker;
use super::client::SharedBrokerClient;
use super::message::Message;
use crate::util::clock::SharedClock;
use std::sync::Arc;

/// Publishes to one topic through a [`BrokerClient`] — the local broker or
/// a remote one behind a transport connection. Cheap to clone/create; the
/// per-publish cost is one (sharded, read-locked) topic lookup on the
/// local path, paid once per *batch* on the batch-first APIs.
///
/// [`BrokerClient`]: super::client::BrokerClient
pub struct Producer {
    client: SharedBrokerClient,
    topic: String,
    clock: SharedClock,
}

impl Producer {
    /// Producer for the in-process broker (the common case).
    pub fn new(broker: &Arc<Broker>, topic: &str, clock: SharedClock) -> Self {
        Producer::with_client(broker.clone(), topic, clock)
    }

    /// Producer over any [`BrokerClient`] (local or remote). Panics if the
    /// topic does not exist — a config error, same as the local path.
    ///
    /// [`BrokerClient`]: super::client::BrokerClient
    pub fn with_client(client: SharedBrokerClient, topic: &str, clock: SharedClock) -> Self {
        assert!(client.partition_count(topic).is_some(), "unknown topic '{topic}'");
        Producer { client, topic: topic.to_string(), clock }
    }

    /// Publish a payload; returns `(partition, offset)`.
    pub fn send(&self, key: Option<u64>, payload: Vec<u8>) -> (usize, u64) {
        self.send_message(Message::new(key, payload, 0))
    }

    /// Publish a pre-built message, restamping its produce time.
    pub fn send_message(&self, mut msg: Message) -> (usize, u64) {
        msg.produced_at_ms = self.clock.now_millis();
        self.client
            .publish_batch(&self.topic, vec![msg])
            .into_iter()
            .next()
            .expect("publish placed one message")
    }

    /// Publish a batch of `(key, payload)` pairs in one shot — one clock
    /// read and one broker round trip for the whole batch, instead of one
    /// of each per message. Returns `(partition, offset)` per input, in
    /// input order; per-key order is preserved (see
    /// [`Topic::publish_batch`](super::broker::Topic::publish_batch)).
    pub fn send_batch(&self, batch: Vec<(Option<u64>, Vec<u8>)>) -> Vec<(usize, u64)> {
        let now = self.clock.now_millis();
        self.client.publish_batch(
            &self.topic,
            batch.into_iter().map(|(k, p)| Message::new(k, p, now)).collect(),
        )
    }

    /// Publish pre-built messages as one batch, restamping all of their
    /// produce times with a single clock read.
    pub fn send_messages(&self, mut msgs: Vec<Message>) -> Vec<(usize, u64)> {
        let now = self.clock.now_millis();
        for m in &mut msgs {
            m.produced_at_ms = now;
        }
        self.client.publish_batch(&self.topic, msgs)
    }

    pub fn topic_name(&self) -> &str {
        &self.topic
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::time::Duration;

    #[test]
    fn stamps_produce_time() {
        let b = Broker::new();
        b.create_topic("t", 2);
        let clock = Arc::new(ManualClock::new());
        let p = Producer::new(&b, "t", clock.clone());
        clock.advance(Duration::from_millis(123));
        p.send(None, vec![1]);
        let c = b.subscribe("t", "g");
        let got = c.poll(1);
        assert_eq!(got[0].message.produced_at_ms, 123);
    }

    #[test]
    fn send_batch_stamps_once_and_places_all() {
        let b = Broker::new();
        b.create_topic("t", 3);
        let clock = Arc::new(ManualClock::new());
        let p = Producer::new(&b, "t", clock.clone());
        clock.advance(Duration::from_millis(77));
        let placed = p.send_batch((0..9u8).map(|i| (None, vec![i])).collect());
        assert_eq!(placed.len(), 9);
        let t = b.topic("t").unwrap();
        assert_eq!(t.total_messages(), 9);
        let c = b.subscribe("t", "g");
        for om in c.poll(9) {
            assert_eq!(om.message.produced_at_ms, 77, "one clock stamp for the whole batch");
        }
    }

    #[test]
    #[should_panic(expected = "unknown topic")]
    fn unknown_topic_panics() {
        let b = Broker::new();
        let clock: SharedClock = Arc::new(ManualClock::new());
        let _ = Producer::new(&b, "missing", clock);
    }
}
