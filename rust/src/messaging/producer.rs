//! Producer handle: publishes messages stamped with the experiment clock.

use super::broker::{Broker, Topic};
use super::message::Message;
use crate::util::clock::SharedClock;
use std::sync::Arc;

/// Publishes to one topic. Cheap to clone/create; holds the topic `Arc`
/// directly so the hot path skips the broker's topic map.
pub struct Producer {
    topic: Arc<Topic>,
    clock: SharedClock,
}

impl Producer {
    pub fn new(broker: &Arc<Broker>, topic: &str, clock: SharedClock) -> Self {
        let topic = broker.topic(topic).unwrap_or_else(|| panic!("unknown topic '{topic}'"));
        Producer { topic, clock }
    }

    /// Publish a payload; returns `(partition, offset)`.
    pub fn send(&self, key: Option<u64>, payload: Vec<u8>) -> (usize, u64) {
        self.topic.publish(Message::new(key, payload, self.clock.now_millis()))
    }

    /// Publish a pre-built message, restamping its produce time.
    pub fn send_message(&self, mut msg: Message) -> (usize, u64) {
        msg.produced_at_ms = self.clock.now_millis();
        self.topic.publish(msg)
    }

    /// Publish a batch of `(key, payload)` pairs in one shot — one clock
    /// read and one partition-log tail publish per touched partition,
    /// instead of one of each per message. Returns `(partition, offset)`
    /// per input, in input order; per-key order is preserved (see
    /// [`Topic::publish_batch`]).
    pub fn send_batch(&self, batch: Vec<(Option<u64>, Vec<u8>)>) -> Vec<(usize, u64)> {
        let now = self.clock.now_millis();
        self.topic
            .publish_batch(batch.into_iter().map(|(k, p)| Message::new(k, p, now)).collect())
    }

    /// Publish pre-built messages as one batch, restamping all of their
    /// produce times with a single clock read.
    pub fn send_messages(&self, mut msgs: Vec<Message>) -> Vec<(usize, u64)> {
        let now = self.clock.now_millis();
        for m in &mut msgs {
            m.produced_at_ms = now;
        }
        self.topic.publish_batch(msgs)
    }

    pub fn topic_name(&self) -> &str {
        &self.topic.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;
    use std::time::Duration;

    #[test]
    fn stamps_produce_time() {
        let b = Broker::new();
        b.create_topic("t", 2);
        let clock = Arc::new(ManualClock::new());
        let p = Producer::new(&b, "t", clock.clone());
        clock.advance(Duration::from_millis(123));
        p.send(None, vec![1]);
        let c = b.subscribe("t", "g");
        let got = c.poll(1);
        assert_eq!(got[0].message.produced_at_ms, 123);
    }

    #[test]
    fn send_batch_stamps_once_and_places_all() {
        let b = Broker::new();
        b.create_topic("t", 3);
        let clock = Arc::new(ManualClock::new());
        let p = Producer::new(&b, "t", clock.clone());
        clock.advance(Duration::from_millis(77));
        let placed = p.send_batch((0..9u8).map(|i| (None, vec![i])).collect());
        assert_eq!(placed.len(), 9);
        let t = b.topic("t").unwrap();
        assert_eq!(t.total_messages(), 9);
        let c = b.subscribe("t", "g");
        for om in c.poll(9) {
            assert_eq!(om.message.produced_at_ms, 77, "one clock stamp for the whole batch");
        }
    }

    #[test]
    #[should_panic(expected = "unknown topic")]
    fn unknown_topic_panics() {
        let b = Broker::new();
        let clock: SharedClock = Arc::new(ManualClock::new());
        let _ = Producer::new(&b, "missing", clock);
    }
}
