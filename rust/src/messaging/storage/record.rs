//! On-disk record codec: one length-prefixed, CRC-sealed message.
//!
//! # Layout (all integers little-endian)
//!
//! | bytes | field           | notes                                        |
//! |-------|-----------------|----------------------------------------------|
//! | 4     | `len`           | byte count of the body (`kind`..payload)     |
//! | 4     | `crc32`         | IEEE CRC-32 over the `len` body bytes        |
//! | 1     | `kind`          | [`KIND_MESSAGE`]                             |
//! | 1     | `key tag`       | 0 = keyless, 1 = keyed                       |
//! | 8     | `key`           | present iff tag = 1                          |
//! | 8     | `produced_at_ms`| broker-ingest timestamp                      |
//! | 4     | `payload len`   | must equal the bytes remaining in the body   |
//! | n     | `payload`       |                                              |
//!
//! The CRC is the same IEEE polynomial the wire protocol uses
//! ([`crate::util::crc::crc32`]), and the decode contract is the same as
//! the frame codec's: **arbitrary bytes never panic** — they produce
//! [`RecordError::Truncated`] (fewer bytes than the record claims; at a
//! file tail this is a torn write) or [`RecordError::Corrupt`]
//! (structurally impossible or CRC-failed). Recovery truncates at the
//! first record that fails to decode.

use crate::messaging::message::Message;
use crate::util::crc::crc32;

/// `len` + `crc32` — the bytes before the body.
pub const RECORD_HEADER: usize = 8;

/// The only record kind today. The byte exists so checkpoint markers or
/// control records can share segment files in a later revision.
pub const KIND_MESSAGE: u8 = 1;

/// Smallest legal body: kind + key tag + produced_at_ms + payload length.
pub const MIN_BODY: usize = 1 + 1 + 8 + 4;

/// Ceiling on one record body — mirrors the wire layer's `MAX_FRAME`, so
/// anything publishable over the wire is storable and a corrupt length
/// prefix can never drive a huge allocation.
pub const MAX_BODY: usize = 8 * 1024 * 1024;

/// Why a byte run failed to decode as a record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecordError {
    /// Fewer bytes than one complete record — at EOF this is a torn tail.
    Truncated,
    /// Structurally invalid: length out of bounds, CRC mismatch, unknown
    /// kind/tag, or body/payload length disagreement.
    Corrupt(&'static str),
}

/// Append the encoded form of `msg` to `out`. Returns the encoded length.
pub fn encode_into(out: &mut Vec<u8>, msg: &Message) -> usize {
    let start = out.len();
    out.extend_from_slice(&[0u8; RECORD_HEADER]); // patched below
    let body = out.len();
    out.push(KIND_MESSAGE);
    match msg.key {
        None => out.push(0),
        Some(k) => {
            out.push(1);
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out.extend_from_slice(&msg.produced_at_ms.to_le_bytes());
    out.extend_from_slice(&(msg.payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&msg.payload);
    let len = out.len() - body;
    assert!(len <= MAX_BODY, "record body {len} exceeds MAX_BODY");
    let crc = crc32(&out[body..]);
    out[start..start + 4].copy_from_slice(&(len as u32).to_le_bytes());
    out[start + 4..start + 8].copy_from_slice(&crc.to_le_bytes());
    out.len() - start
}

/// Decode one record from the start of `buf`, returning the message and
/// the encoded length consumed.
pub fn decode(buf: &[u8]) -> Result<(Message, usize), RecordError> {
    if buf.len() < RECORD_HEADER {
        return Err(RecordError::Truncated);
    }
    let len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    if !(MIN_BODY..=MAX_BODY).contains(&len) {
        return Err(RecordError::Corrupt("body length out of bounds"));
    }
    if buf.len() < RECORD_HEADER + len {
        return Err(RecordError::Truncated);
    }
    let stored = u32::from_le_bytes(buf[4..8].try_into().unwrap());
    let body = &buf[RECORD_HEADER..RECORD_HEADER + len];
    if crc32(body) != stored {
        return Err(RecordError::Corrupt("CRC mismatch"));
    }
    let msg = decode_body(body)?;
    Ok((msg, RECORD_HEADER + len))
}

/// Decode a record body whose CRC has already been verified. Split out so
/// streaming readers that reassemble `body` from a file can share the
/// parse. Length bounds are re-checked; CRC is the caller's job.
pub fn decode_body(body: &[u8]) -> Result<Message, RecordError> {
    if body.len() < MIN_BODY {
        return Err(RecordError::Corrupt("body shorter than minimum"));
    }
    if body[0] != KIND_MESSAGE {
        return Err(RecordError::Corrupt("unknown record kind"));
    }
    let mut at = 2;
    let key = match body[1] {
        0 => None,
        1 => {
            if body.len() < at + 8 + 12 {
                return Err(RecordError::Corrupt("keyed body too short"));
            }
            let k = u64::from_le_bytes(body[at..at + 8].try_into().unwrap());
            at += 8;
            Some(k)
        }
        _ => return Err(RecordError::Corrupt("unknown key tag")),
    };
    let produced = u64::from_le_bytes(body[at..at + 8].try_into().unwrap());
    at += 8;
    let paylen = u32::from_le_bytes(body[at..at + 4].try_into().unwrap()) as usize;
    at += 4;
    if body.len() - at != paylen {
        return Err(RecordError::Corrupt("payload length disagrees with body"));
    }
    Ok(Message::new(key, body[at..].to_vec(), produced))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encode(msg: &Message) -> Vec<u8> {
        let mut out = Vec::new();
        encode_into(&mut out, msg);
        out
    }

    #[test]
    fn round_trip_keyless_keyed_empty() {
        for msg in [
            Message::new(None, b"hello".to_vec(), 7),
            Message::new(Some(0xDEAD_BEEF), b"keyed payload".to_vec(), u64::MAX),
            Message::new(None, Vec::new(), 0),
            Message::new(Some(0), vec![0u8; 1000], 1),
        ] {
            let buf = encode(&msg);
            let (got, used) = decode(&buf).expect("round trip");
            assert_eq!(got, msg);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn decode_consumes_exactly_one_record() {
        let a = Message::new(None, b"first".to_vec(), 1);
        let b = Message::new(Some(9), b"second".to_vec(), 2);
        let mut buf = encode(&a);
        let a_len = buf.len();
        encode_into(&mut buf, &b);
        let (got_a, used) = decode(&buf).unwrap();
        assert_eq!(got_a, a);
        assert_eq!(used, a_len);
        let (got_b, _) = decode(&buf[used..]).unwrap();
        assert_eq!(got_b, b);
    }

    #[test]
    fn every_strict_prefix_fails_cleanly() {
        // A torn tail can cut a record at *any* byte; each cut must be an
        // error (never a panic, never a bogus success).
        let buf = encode(&Message::new(Some(42), b"torn tail target".to_vec(), 3));
        for cut in 0..buf.len() {
            let err = decode(&buf[..cut]).expect_err("prefix decoded");
            assert!(
                matches!(err, RecordError::Truncated | RecordError::Corrupt(_)),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let msg = Message::new(Some(7), b"bitflip coverage".to_vec(), 5);
        let good = encode(&msg);
        let mut buf = good.clone();
        for byte in 0..buf.len() {
            for bit in 0..8u8 {
                buf[byte] ^= 1 << bit;
                match decode(&buf) {
                    // A flip in the length prefix may claim more bytes
                    // than exist (Truncated) or an illegal size
                    // (Corrupt); anywhere else the CRC or the body
                    // structure must catch it.
                    Err(_) => {}
                    Ok((got, _)) => {
                        panic!("flip at byte {byte} bit {bit} decoded as {got:?}")
                    }
                }
                buf[byte] ^= 1 << bit;
            }
        }
        assert_eq!(buf, good);
    }

    #[test]
    fn zero_filled_bytes_rejected() {
        // A zero-filled page (all-zero length = below MIN_BODY) must be
        // flagged as corrupt, not read as an empty record.
        let zeros = vec![0u8; 4096];
        assert!(matches!(decode(&zeros), Err(RecordError::Corrupt(_))));
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocation() {
        let mut buf = vec![0u8; 64];
        buf[0..4].copy_from_slice(&(u32::MAX).to_le_bytes());
        assert!(matches!(decode(&buf), Err(RecordError::Corrupt(_))));
    }
}
