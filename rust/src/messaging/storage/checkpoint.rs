//! Compacted, CRC-sealed tables: the consumer-offset checkpoint
//! (`offsets.ckpt`) and the topic manifest (`topics.meta`).
//!
//! Both are tiny (entries, not history), so "compaction" is structural:
//! every write rewrites the full current table — one live value per key,
//! nothing to replay — via the classic atomic pattern: write `<file>.tmp`,
//! optionally fdatasync, then `rename` over the live file. A reader (or a
//! recovering broker) therefore sees either the old table or the new one,
//! never a torn mix; a crash mid-write leaves at most a stale `.tmp` that
//! the next write overwrites.
//!
//! # Sealed-table layout (little-endian)
//!
//! | bytes | field                            |
//! |-------|----------------------------------|
//! | 8     | magic (`RLCKPT1\n` / `RLMETA1\n`)|
//! | n     | body (table-specific)            |
//! | 4     | CRC-32 over magic + body         |
//!
//! Checkpoint body: `count u32`, then per entry `topic str16`,
//! `group str16`, `partition u32`, `next u64`. Manifest body: `count u32`,
//! then per entry `name str16`, `dir str16`, `partitions u32`. (`str16` =
//! u16 length + UTF-8 bytes, the wire protocol's string form.)

use super::StorageError;
use crate::util::crc::crc32;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

pub const CKPT_MAGIC: &[u8; 8] = b"RLCKPT1\n";
pub const META_MAGIC: &[u8; 8] = b"RLMETA1\n";

/// Ceiling on either table file — far above any real table, low enough
/// that a corrupt length field can never drive a huge allocation.
const MAX_TABLE: u64 = 64 * 1024 * 1024;

// ------------------------------------------------------------ seal/unseal

/// Atomically replace `path` with `magic + body + crc`.
pub fn write_sealed(path: &Path, magic: &[u8; 8], body: &[u8], fsync: bool) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(8 + body.len() + 4);
    buf.extend_from_slice(magic);
    buf.extend_from_slice(body);
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());

    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        if fsync {
            f.sync_data()?;
        }
    }
    std::fs::rename(&tmp, path)?;
    if fsync {
        // Make the rename itself durable (fsync the parent directory).
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Read and verify a sealed table. `Ok(None)` when the file does not
/// exist; `Err(Corrupt)` when it exists but fails the magic/CRC/size
/// checks; `Ok(Some(body))` otherwise.
pub fn read_sealed(path: &Path, magic: &[u8; 8]) -> Result<Option<Vec<u8>>, StorageError> {
    let meta = match std::fs::metadata(path) {
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(StorageError::Io(e)),
        Ok(m) => m,
    };
    if meta.len() > MAX_TABLE {
        return Err(StorageError::Corrupt(format!(
            "{}: {} bytes exceeds the table ceiling",
            path.display(),
            meta.len()
        )));
    }
    let bytes = std::fs::read(path).map_err(StorageError::Io)?;
    if bytes.len() < 12 || &bytes[0..8] != magic {
        return Err(StorageError::Corrupt(format!("{}: bad table magic", path.display())));
    }
    let split = bytes.len() - 4;
    let stored = u32::from_le_bytes(bytes[split..].try_into().unwrap());
    if crc32(&bytes[..split]) != stored {
        return Err(StorageError::Corrupt(format!("{}: table CRC mismatch", path.display())));
    }
    Ok(Some(bytes[8..split].to_vec()))
}

// ----------------------------------------------------------- body codecs

fn put_str(out: &mut Vec<u8>, s: &str) {
    assert!(s.len() <= u16::MAX as usize, "table string too long");
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(buf: &[u8], at: &mut usize) -> Result<String, StorageError> {
    let malformed = || StorageError::Corrupt("malformed table body".to_string());
    if buf.len() < *at + 2 {
        return Err(malformed());
    }
    let len = u16::from_le_bytes(buf[*at..*at + 2].try_into().unwrap()) as usize;
    *at += 2;
    if buf.len() < *at + len {
        return Err(malformed());
    }
    let s = std::str::from_utf8(&buf[*at..*at + len]).map_err(|_| malformed())?.to_string();
    *at += len;
    Ok(s)
}

fn get_u32(buf: &[u8], at: &mut usize) -> Result<u32, StorageError> {
    if buf.len() < *at + 4 {
        return Err(StorageError::Corrupt("malformed table body".to_string()));
    }
    let v = u32::from_le_bytes(buf[*at..*at + 4].try_into().unwrap());
    *at += 4;
    Ok(v)
}

fn get_u64(buf: &[u8], at: &mut usize) -> Result<u64, StorageError> {
    if buf.len() < *at + 8 {
        return Err(StorageError::Corrupt("malformed table body".to_string()));
    }
    let v = u64::from_le_bytes(buf[*at..*at + 8].try_into().unwrap());
    *at += 8;
    Ok(v)
}

/// The committed-offsets table: `(topic, group, partition) → next offset`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CheckpointTable {
    pub entries: BTreeMap<(String, String, u32), u64>,
}

impl CheckpointTable {
    /// Apply one commit, keeping the table monotonic per key (a racing
    /// stale writer can never regress a newer commit). Returns whether
    /// the table changed.
    pub fn apply(&mut self, topic: &str, group: &str, partition: u32, next: u64) -> bool {
        let key = (topic.to_string(), group.to_string(), partition);
        match self.entries.get(&key) {
            Some(&cur) if cur >= next => false,
            _ => {
                self.entries.insert(key, next);
                true
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for ((topic, group, partition), next) in &self.entries {
            put_str(&mut out, topic);
            put_str(&mut out, group);
            out.extend_from_slice(&partition.to_le_bytes());
            out.extend_from_slice(&next.to_le_bytes());
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<CheckpointTable, StorageError> {
        let mut at = 0;
        let count = get_u32(body, &mut at)?;
        let mut entries = BTreeMap::new();
        for _ in 0..count {
            let topic = get_str(body, &mut at)?;
            let group = get_str(body, &mut at)?;
            let partition = get_u32(body, &mut at)?;
            let next = get_u64(body, &mut at)?;
            entries.insert((topic, group, partition), next);
        }
        if at != body.len() {
            return Err(StorageError::Corrupt("trailing bytes after checkpoint table".into()));
        }
        Ok(CheckpointTable { entries })
    }

    /// Load from disk. Missing file → empty table. A corrupt file is an
    /// error so the *caller* chooses the policy (the broker warns and
    /// redelivers from zero — at-least-once allows it; losing commits is
    /// redelivery, losing data would be loss).
    pub fn load(path: &Path) -> Result<CheckpointTable, StorageError> {
        match read_sealed(path, CKPT_MAGIC)? {
            None => Ok(CheckpointTable::default()),
            Some(body) => Self::decode(&body),
        }
    }

    pub fn store(&self, path: &Path, fsync: bool) -> std::io::Result<()> {
        write_sealed(path, CKPT_MAGIC, &self.encode(), fsync)
    }
}

/// The topic manifest: `name → (directory, partitions)`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Manifest {
    pub topics: BTreeMap<String, (String, u32)>,
}

impl Manifest {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(self.topics.len() as u32).to_le_bytes());
        for (name, (dir, partitions)) in &self.topics {
            put_str(&mut out, name);
            put_str(&mut out, dir);
            out.extend_from_slice(&partitions.to_le_bytes());
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<Manifest, StorageError> {
        let mut at = 0;
        let count = get_u32(body, &mut at)?;
        let mut topics = BTreeMap::new();
        for _ in 0..count {
            let name = get_str(body, &mut at)?;
            let dir = get_str(body, &mut at)?;
            let partitions = get_u32(body, &mut at)?;
            if partitions == 0 {
                return Err(StorageError::Corrupt(format!(
                    "manifest topic '{name}' claims zero partitions"
                )));
            }
            topics.insert(name, (dir, partitions));
        }
        if at != body.len() {
            return Err(StorageError::Corrupt("trailing bytes after manifest".into()));
        }
        Ok(Manifest { topics })
    }

    /// Load from disk; missing file → empty manifest; corrupt file →
    /// error (the broker **refuses** to start on a bad manifest — unlike
    /// commits, guessing here could resurrect wrong topology).
    pub fn load(path: &Path) -> Result<Manifest, StorageError> {
        match read_sealed(path, META_MAGIC)? {
            None => Ok(Manifest::default()),
            Some(body) => Self::decode(&body),
        }
    }

    pub fn store(&self, path: &Path) -> std::io::Result<()> {
        // The manifest is rewritten only on topic creation; always fsync.
        write_sealed(path, META_MAGIC, &self.encode(), true)
    }
}

/// Directory name for a topic: a sanitized, length-capped prefix of the
/// name plus an FNV-1a hash of the full name, so any two distinct topic
/// names map to distinct directories regardless of what characters or
/// lengths the names use.
pub fn topic_dir_name(name: &str) -> String {
    let safe: String = name
        .chars()
        .take(32)
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect();
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    format!("{safe}-{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("rl_ckpt_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn checkpoint_round_trip_and_monotonicity() {
        let dir = tmp("rt");
        let path = dir.join("offsets.ckpt");
        let mut t = CheckpointTable::default();
        assert!(t.apply("orders", "workers", 0, 10));
        assert!(t.apply("orders", "workers", 1, 4));
        assert!(t.apply("clicks", "audit", 0, 99));
        assert!(!t.apply("orders", "workers", 0, 7), "stale commit ignored");
        assert!(!t.apply("orders", "workers", 0, 10), "equal commit is a no-op");
        assert!(t.apply("orders", "workers", 0, 11));
        t.store(&path, true).unwrap();
        let back = CheckpointTable::load(&path).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.entries[&("orders".into(), "workers".into(), 0)], 11);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_files_load_empty() {
        let dir = tmp("missing");
        assert_eq!(CheckpointTable::load(&dir.join("none.ckpt")).unwrap(), Default::default());
        assert_eq!(Manifest::load(&dir.join("none.meta")).unwrap(), Default::default());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_tables_are_errors_not_panics() {
        let dir = tmp("corrupt");
        let path = dir.join("offsets.ckpt");
        let mut t = CheckpointTable::default();
        t.apply("a", "g", 0, 5);
        t.store(&path, false).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Flip every byte in turn: every variant must error cleanly.
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x55;
            std::fs::write(&path, &bad).unwrap();
            assert!(CheckpointTable::load(&path).is_err(), "flip at byte {i} accepted");
        }
        // Truncations too.
        for cut in 0..good.len() {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(CheckpointTable::load(&path).is_err(), "cut at {cut} accepted");
        }
        // Arbitrary garbage.
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(CheckpointTable::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_round_trip_and_zero_partitions_rejected() {
        let dir = tmp("manifest");
        let path = dir.join("topics.meta");
        let mut m = Manifest::default();
        m.topics.insert("orders".into(), (topic_dir_name("orders"), 4));
        m.topics.insert("weird/topic name".into(), (topic_dir_name("weird/topic name"), 1));
        m.store(&path).unwrap();
        let back = Manifest::load(&path).unwrap();
        assert_eq!(back, m);

        let mut zero = Manifest::default();
        zero.topics.insert("z".into(), ("z-0".into(), 0));
        // Hand-encode with zero partitions: decode must reject.
        assert!(Manifest::decode(&zero.encode()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_rewrite_leaves_no_tmp_visible() {
        let dir = tmp("atomic");
        let path = dir.join("offsets.ckpt");
        let mut t = CheckpointTable::default();
        for i in 0..50u32 {
            t.apply("t", "g", i % 4, i as u64);
            t.store(&path, false).unwrap();
            assert!(CheckpointTable::load(&path).is_ok(), "live file always valid");
        }
        assert!(!path.with_extension("tmp").exists(), "tmp renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn topic_dir_names_distinct_and_safe() {
        let a = topic_dir_name("orders");
        let b = topic_dir_name("orders2");
        assert_ne!(a, b);
        let weird = topic_dir_name("../../etc/passwd");
        assert!(!weird.contains('/'), "path separators sanitized: {weird}");
        // Same 32-char prefix, different tails: hash disambiguates.
        let long_a = topic_dir_name(&format!("{}{}", "x".repeat(32), "a"));
        let long_b = topic_dir_name(&format!("{}{}", "x".repeat(32), "b"));
        assert_ne!(long_a, long_b);
    }
}
