//! Sparse offset index sidecar (`<base>.idx`, next to its `.seg`).
//!
//! # Layout (little-endian)
//!
//! | bytes | field                                         |
//! |-------|-----------------------------------------------|
//! | 8     | magic `RLIDX01\n`                             |
//! | 8     | segment base offset                           |
//! | 4     | CRC-32 over magic + base                      |
//! | 12·k  | entries: `rel` u32 (offset − base), `pos` u64 |
//!
//! One entry is written every `index_every` records, so a seek to offset
//! `o` starts scanning at most `index_every − 1` records before it
//! instead of at the segment head.
//!
//! The index is **advisory and never trusted**: [`load`] validates the
//! header, entry alignment, monotonicity and position bounds, and returns
//! `None` on *any* anomaly — readers then fall back to a full scan from
//! the segment header. A torn entry at the tail (the writer died
//! mid-append) silently drops the partial entry, because losing index
//! density costs a longer scan, never correctness.

use crate::util::crc::crc32;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub const IDX_MAGIC: &[u8; 8] = b"RLIDX01\n";
pub const IDX_HEADER: usize = 20;
pub const IDX_ENTRY: usize = 12;

fn header_bytes(base: u64) -> [u8; IDX_HEADER] {
    let mut h = [0u8; IDX_HEADER];
    h[0..8].copy_from_slice(IDX_MAGIC);
    h[8..16].copy_from_slice(&base.to_le_bytes());
    let crc = crc32(&h[0..16]);
    h[16..20].copy_from_slice(&crc.to_le_bytes());
    h
}

/// Incremental index writer for the segment currently being appended.
pub struct IndexWriter {
    w: BufWriter<File>,
}

impl IndexWriter {
    /// Create (truncating any stale file) with a fresh header.
    pub fn create(path: &Path, base: u64) -> std::io::Result<IndexWriter> {
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(&header_bytes(base))?;
        w.flush()?;
        Ok(IndexWriter { w })
    }

    /// Open for appending more entries after recovery rewrote the file.
    pub fn append_to(path: &Path) -> std::io::Result<IndexWriter> {
        let f = std::fs::OpenOptions::new().append(true).open(path)?;
        Ok(IndexWriter { w: BufWriter::new(f) })
    }

    pub fn push(&mut self, rel: u32, pos: u64) -> std::io::Result<()> {
        self.w.write_all(&rel.to_le_bytes())?;
        self.w.write_all(&pos.to_le_bytes())?;
        Ok(())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Rewrite the whole index from scratch (recovery after a truncation).
pub fn rewrite(path: &Path, base: u64, entries: &[(u32, u64)]) -> std::io::Result<IndexWriter> {
    let mut w = IndexWriter::create(path, base)?;
    for &(rel, pos) in entries {
        w.push(rel, pos)?;
    }
    w.flush()?;
    Ok(w)
}

/// Load and validate the index for a segment with base `expected_base`
/// whose data file is `seg_len` bytes. Returns `None` — scan from the
/// header instead — on any anomaly.
pub fn load(path: &Path, expected_base: u64, seg_len: u64) -> Option<Vec<(u32, u64)>> {
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() < IDX_HEADER {
        return None;
    }
    if &bytes[0..8] != IDX_MAGIC {
        return None;
    }
    let base = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let stored = u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    if base != expected_base || crc32(&bytes[0..16]) != stored {
        return None;
    }
    // Whole entries only; a torn trailing entry is dropped.
    let body = &bytes[IDX_HEADER..];
    let whole = body.len() / IDX_ENTRY;
    let mut entries = Vec::with_capacity(whole);
    let mut prev_rel: i64 = -1;
    let mut prev_pos: u64 = 0;
    for i in 0..whole {
        let at = i * IDX_ENTRY;
        let rel = u32::from_le_bytes(body[at..at + 4].try_into().unwrap());
        let pos = u64::from_le_bytes(body[at + 4..at + 12].try_into().unwrap());
        // Entries must advance in both coordinates and point inside the
        // segment's data region; anything else means the file is not an
        // index for this segment.
        if (rel as i64) <= prev_rel || (i > 0 && pos <= prev_pos) {
            return None;
        }
        if pos < super::segment::SEG_HEADER as u64 || pos >= seg_len {
            return None;
        }
        prev_rel = rel as i64;
        prev_pos = pos;
        entries.push((rel, pos));
    }
    Some(entries)
}

/// Greatest entry at or below `rel`, or the segment-header start when the
/// index has nothing that early.
pub fn lookup(entries: &[(u32, u64)], rel: u32) -> (u32, u64) {
    let mut best = (0u32, super::segment::SEG_HEADER as u64);
    for &(r, p) in entries {
        if r <= rel {
            best = (r, p);
        } else {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("rl_idx_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("0.idx")
    }

    #[test]
    fn round_trip_and_lookup() {
        let path = tmp("rt");
        let mut w = IndexWriter::create(&path, 100).unwrap();
        w.push(0, 20).unwrap();
        w.push(64, 5000).unwrap();
        w.push(128, 11000).unwrap();
        w.flush().unwrap();
        let entries = load(&path, 100, 20_000).expect("valid index");
        assert_eq!(entries, vec![(0, 20), (64, 5000), (128, 11000)]);
        assert_eq!(lookup(&entries, 0), (0, 20));
        assert_eq!(lookup(&entries, 63), (0, 20));
        assert_eq!(lookup(&entries, 64), (64, 5000));
        assert_eq!(lookup(&entries, 1000), (128, 11000));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_base_or_magic_discarded() {
        let path = tmp("base");
        let mut w = IndexWriter::create(&path, 7).unwrap();
        w.push(0, 20).unwrap();
        w.flush().unwrap();
        assert!(load(&path, 8, 1000).is_none(), "base mismatch");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path, 7, 1000).is_none(), "bad magic");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_entry_dropped_not_fatal() {
        let path = tmp("torn");
        let mut w = IndexWriter::create(&path, 0).unwrap();
        w.push(0, 20).unwrap();
        w.push(64, 900).unwrap();
        w.flush().unwrap();
        drop(w);
        // Tear the last entry in half.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let entries = load(&path, 0, 10_000).expect("prefix still valid");
        assert_eq!(entries, vec![(0, 20)]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_monotonic_or_out_of_range_discarded() {
        let path = tmp("mono");
        let mut w = IndexWriter::create(&path, 0).unwrap();
        w.push(64, 900).unwrap();
        w.push(32, 1200).unwrap(); // rel regresses
        w.flush().unwrap();
        assert!(load(&path, 0, 10_000).is_none());
        let mut w = IndexWriter::create(&path, 0).unwrap();
        w.push(0, 99_999).unwrap(); // pos past the segment
        w.flush().unwrap();
        assert!(load(&path, 0, 10_000).is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_none() {
        assert!(load(Path::new("/nonexistent/rl.idx"), 0, 10).is_none());
    }
}
