//! [`DiskStorage`]: the real on-disk [`Storage`] backend.
//!
//! # Directory layout
//!
//! ```text
//! <data-dir>/
//!   topics.meta                  topic manifest (sealed, atomic rewrite)
//!   offsets.ckpt                 committed offsets (sealed, atomic rewrite)
//!   <topic-dir>/p<partition>/    one directory per partition
//!     00000000000000000000.seg   segment chain (+ .idx sidecars)
//!     00000000000000004096.seg
//! ```
//!
//! # Write path
//!
//! [`PartitionLog`](crate::messaging::partition::PartitionLog) calls
//! [`super::PartitionStore::append_batch`] with its writer mutex held and
//! **before** publishing the batch to in-memory readers, so disk order,
//! memory order, and acked offsets always agree. Every append ends with a
//! buffer flush (a `write` syscall), which makes acked messages survive
//! `kill -9` under *any* fsync policy — the policy only decides when
//! `fdatasync` pushes them past the OS cache for power-loss durability:
//!
//! - [`FsyncPolicy::PerBatch`] — fdatasync before the append returns;
//! - [`FsyncPolicy::IntervalMs`] — a background flusher fdatasyncs dirty
//!   partitions (and the checkpoint) on the interval;
//! - [`FsyncPolicy::Off`] — never, except on segment roll and shutdown.
//!
//! # Recovery
//!
//! [`DiskStorage::open`] loads the manifest and checkpoint; the broker
//! then opens each partition, which scans its segment chain: damage in
//! the **last** segment is a torn tail — truncated to the last valid CRC
//! boundary and the index rebuilt — while damage in any earlier segment
//! (or a broken chain) would make offsets non-dense, so the open refuses
//! with [`StorageError::Corrupt`]. A corrupt checkpoint degrades to full
//! redelivery (with a warning), never to data loss.

use super::checkpoint::{topic_dir_name, CheckpointTable, Manifest};
use super::segment::{self, SegmentWriter};
use super::{CommitEntry, FsyncPolicy, PartitionStore, Storage, StorageConfig, StorageError, TopicMeta};
use crate::messaging::message::Message;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

const MANIFEST_FILE: &str = "topics.meta";
const CHECKPOINT_FILE: &str = "offsets.ckpt";

/// On-disk storage rooted at one data directory.
pub struct DiskStorage {
    root: PathBuf,
    cfg: StorageConfig,
    manifest: Mutex<Manifest>,
    ckpt: Mutex<CkptState>,
    /// Every partition store opened through this storage, for the
    /// interval flusher and shutdown sync.
    parts: Mutex<Vec<Arc<DiskPartitionStore>>>,
    stop_flusher: Arc<AtomicBool>,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

struct CkptState {
    table: CheckpointTable,
    dirty: bool,
}

impl DiskStorage {
    /// Open (creating the directory if needed) and load the manifest and
    /// checkpoint. A corrupt manifest refuses; a corrupt checkpoint warns
    /// and degrades to full redelivery.
    pub fn open(root: &Path, cfg: StorageConfig) -> Result<Arc<DiskStorage>, StorageError> {
        std::fs::create_dir_all(root).map_err(StorageError::Io)?;
        let manifest = Manifest::load(&root.join(MANIFEST_FILE))?;
        let table = match CheckpointTable::load(&root.join(CHECKPOINT_FILE)) {
            Ok(t) => t,
            Err(e) => {
                crate::log_warn!(
                    "storage",
                    "checkpoint unreadable ({e}); groups restart from offset 0 (full redelivery)"
                );
                CheckpointTable::default()
            }
        };
        let storage = Arc::new(DiskStorage {
            root: root.to_path_buf(),
            cfg,
            manifest: Mutex::new(manifest),
            ckpt: Mutex::new(CkptState { table, dirty: false }),
            parts: Mutex::new(Vec::new()),
            stop_flusher: Arc::new(AtomicBool::new(false)),
            flusher: Mutex::new(None),
        });
        if let FsyncPolicy::IntervalMs(ms) = cfg.fsync {
            let weak = Arc::downgrade(&storage);
            let stop = storage.stop_flusher.clone();
            let handle = std::thread::Builder::new()
                .name("rl-storage-flush".into())
                .spawn(move || flusher_loop(weak, stop, ms))
                .map_err(StorageError::Io)?;
            *storage.flusher.lock().unwrap() = Some(handle);
        }
        Ok(storage)
    }

    fn ckpt_path(&self) -> PathBuf {
        self.root.join(CHECKPOINT_FILE)
    }

    fn partition_dir(&self, topic: &str, partition: usize) -> Result<PathBuf, StorageError> {
        let manifest = self.manifest.lock().unwrap();
        let (dir, partitions) = manifest.topics.get(topic).ok_or_else(|| {
            StorageError::Corrupt(format!("topic '{topic}' not in the manifest"))
        })?;
        if partition as u32 >= *partitions {
            return Err(StorageError::Corrupt(format!(
                "partition {partition} out of range for topic '{topic}' ({partitions} partitions)"
            )));
        }
        Ok(self.root.join(dir).join(format!("p{partition}")))
    }

    /// Fdatasync everything marked dirty since the last pass.
    fn flush_dirty(&self) {
        let parts: Vec<Arc<DiskPartitionStore>> = self.parts.lock().unwrap().clone();
        for p in parts {
            if p.dirty.swap(false, Ordering::AcqRel) {
                p.sync();
            }
        }
        let mut ckpt = self.ckpt.lock().unwrap();
        if ckpt.dirty {
            if let Err(e) = ckpt.table.store(&self.ckpt_path(), true) {
                crate::log_warn!("storage", "checkpoint flush failed: {e}");
            } else {
                ckpt.dirty = false;
            }
        }
    }
}

fn flusher_loop(storage: Weak<DiskStorage>, stop: Arc<AtomicBool>, interval_ms: u64) {
    let interval = Duration::from_millis(interval_ms.max(1));
    // Sleep in small slices so shutdown never waits a full interval.
    let slice = interval.min(Duration::from_millis(50));
    let mut since_flush = Duration::ZERO;
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        std::thread::sleep(slice);
        since_flush += slice;
        if since_flush < interval {
            continue;
        }
        since_flush = Duration::ZERO;
        match storage.upgrade() {
            None => return,
            Some(s) => s.flush_dirty(),
        }
    }
}

impl Drop for DiskStorage {
    fn drop(&mut self) {
        self.stop_flusher.store(true, Ordering::Release);
        if let Some(h) = self.flusher.get_mut().unwrap().take() {
            let _ = h.join();
        }
        // Graceful shutdown: push everything down so even `off` loses
        // nothing when the process exits cleanly.
        let parts = std::mem::take(&mut *self.parts.lock().unwrap());
        for p in parts {
            p.sync();
        }
        let ckpt = self.ckpt.get_mut().unwrap();
        if ckpt.dirty {
            let _ = ckpt.table.store(&self.root.join(CHECKPOINT_FILE), true);
        }
    }
}

impl Storage for DiskStorage {
    fn policy(&self) -> FsyncPolicy {
        self.cfg.fsync
    }

    fn load_topics(&self) -> Result<Vec<TopicMeta>, StorageError> {
        let manifest = self.manifest.lock().unwrap();
        Ok(manifest
            .topics
            .iter()
            .map(|(name, (_, partitions))| TopicMeta {
                name: name.clone(),
                partitions: *partitions as usize,
            })
            .collect())
    }

    fn create_topic(&self, name: &str, partitions: usize) -> Result<(), StorageError> {
        assert!(partitions >= 1, "topic needs >= 1 partition");
        let mut manifest = self.manifest.lock().unwrap();
        if let Some((_, existing)) = manifest.topics.get(name) {
            if *existing as usize != partitions {
                return Err(StorageError::Corrupt(format!(
                    "topic '{name}' persisted with {existing} partitions, asked for {partitions}"
                )));
            }
            return Ok(());
        }
        let dir = topic_dir_name(name);
        for p in 0..partitions {
            std::fs::create_dir_all(self.root.join(&dir).join(format!("p{p}")))
                .map_err(StorageError::Io)?;
        }
        manifest.topics.insert(name.to_string(), (dir, partitions as u32));
        manifest.store(&self.root.join(MANIFEST_FILE)).map_err(StorageError::Io)?;
        Ok(())
    }

    fn open_partition(
        &self,
        topic: &str,
        partition: usize,
    ) -> Result<(Arc<dyn PartitionStore>, Vec<Message>), StorageError> {
        let dir = self.partition_dir(topic, partition)?;
        std::fs::create_dir_all(&dir).map_err(StorageError::Io)?;

        // Collect the segment chain in base order.
        let mut bases: Vec<u64> = std::fs::read_dir(&dir)
            .map_err(StorageError::Io)?
            .filter_map(|e| e.ok())
            .filter_map(|e| segment::parse_seg_file_name(&e.file_name().to_string_lossy()))
            .collect();
        bases.sort_unstable();

        let mut messages: Vec<Message> = Vec::new();
        let mut writer: Option<SegmentWriter> = None;
        let index_every = self.cfg.index_every.max(1);
        for (i, &base) in bases.iter().enumerate() {
            let last = i + 1 == bases.len();
            // Chain density: each segment must start where the previous
            // one ended (the first at offset 0).
            let expected = messages.len() as u64;
            if base != expected {
                return Err(StorageError::Corrupt(format!(
                    "{}: segment chain gap — found base {base}, expected {expected}",
                    dir.display()
                )));
            }
            let outcome = segment::scan(&dir.join(segment::seg_file_name(base)), base)?;
            match (&outcome.damage, last) {
                (None, _) => {}
                (Some(why), false) => {
                    // Damage before the tail would tear a hole in the
                    // offset space: refuse rather than serve a log with
                    // silently missing acknowledged messages.
                    return Err(StorageError::Corrupt(format!(
                        "damage before the log tail (refusing to open): {why}"
                    )));
                }
                (Some(why), true) => {
                    crate::log_warn!(
                        "storage",
                        "truncating torn tail of {}/{topic}[{partition}]: {why}",
                        dir.display()
                    );
                    segment::truncate_to_valid(&dir, base, &outcome, index_every)?;
                }
            }
            let records = outcome.messages.len() as u64;
            messages.extend(outcome.messages);
            if last {
                writer = Some(
                    SegmentWriter::open_end(
                        &dir,
                        base,
                        if outcome.damage.is_some() {
                            // Repaired length: header-only when the
                            // header itself was rewritten.
                            outcome.valid_len.max(segment::SEG_HEADER as u64)
                        } else {
                            outcome.valid_len
                        },
                        records,
                        index_every,
                    )
                    .map_err(StorageError::Io)?,
                );
            }
        }
        let writer = match writer {
            Some(w) => w,
            None => SegmentWriter::create(&dir, 0, index_every).map_err(StorageError::Io)?,
        };

        let end = writer.end_offset();
        let store = Arc::new(DiskPartitionStore {
            cfg: self.cfg,
            dir,
            state: Mutex::new(writer),
            end: AtomicU64::new(end),
            dirty: AtomicBool::new(false),
        });
        self.parts.lock().unwrap().push(store.clone());
        Ok((store, messages))
    }

    fn load_commits(&self) -> Vec<CommitEntry> {
        let ckpt = self.ckpt.lock().unwrap();
        ckpt.table
            .entries
            .iter()
            .map(|((topic, group, partition), next)| CommitEntry {
                topic: topic.clone(),
                group: group.clone(),
                partition: *partition as usize,
                next: *next,
            })
            .collect()
    }

    fn checkpoint(&self, topic: &str, group: &str, entries: &[(usize, u64)]) {
        let mut ckpt = self.ckpt.lock().unwrap();
        let mut changed = false;
        for &(partition, next) in entries {
            changed |= ckpt.table.apply(topic, group, partition as u32, next);
        }
        if !changed {
            return;
        }
        match self.cfg.fsync {
            // Deferred to the flusher thread.
            FsyncPolicy::IntervalMs(_) => ckpt.dirty = true,
            FsyncPolicy::PerBatch | FsyncPolicy::Off => {
                let fsync = self.cfg.fsync == FsyncPolicy::PerBatch;
                if let Err(e) = ckpt.table.store(&self.ckpt_path(), fsync) {
                    // A commit that cannot persist still committed in
                    // memory; redelivery after restart is the worst case.
                    crate::log_warn!("storage", "checkpoint write failed: {e}");
                    ckpt.dirty = true;
                }
            }
        }
    }

    fn sync(&self) {
        self.flush_dirty();
    }
}

/// Append side of one partition's segment chain.
pub struct DiskPartitionStore {
    cfg: StorageConfig,
    dir: PathBuf,
    state: Mutex<SegmentWriter>,
    end: AtomicU64,
    dirty: AtomicBool,
}

impl DiskPartitionStore {
    /// Read a window straight from the segment files (bypassing the
    /// in-memory log) — verification surface for tests and tools.
    pub fn read_disk(&self, from: u64, max: usize) -> Result<Vec<(u64, Message)>, StorageError> {
        // Hold the writer lock so a concurrent roll cannot swap files
        // mid-read; reads of sealed prefixes do not need it, but this
        // path is for verification, not the hot path.
        let state = self.state.lock().unwrap();
        let mut out = Vec::new();
        let mut bases: Vec<u64> = std::fs::read_dir(&self.dir)
            .map_err(StorageError::Io)?
            .filter_map(|e| e.ok())
            .filter_map(|e| segment::parse_seg_file_name(&e.file_name().to_string_lossy()))
            .collect();
        bases.sort_unstable();
        drop(state);
        for (i, &base) in bases.iter().enumerate() {
            let seg_end = bases.get(i + 1).copied().unwrap_or(u64::MAX);
            if seg_end <= from || out.len() >= max {
                continue;
            }
            let got = segment::read_from(&self.dir, base, from, max - out.len())?;
            out.extend(got);
        }
        Ok(out)
    }
}

impl PartitionStore for DiskPartitionStore {
    fn append_batch(&self, msgs: &[Message]) {
        let mut writer = self.state.lock().unwrap();
        for msg in msgs {
            if writer.len_bytes() >= self.cfg.segment_bytes {
                // Roll: seal the full segment (sync regardless of policy
                // — once per segment, and it makes every non-tail
                // segment stable on disk) and start the next one.
                writer.sync().unwrap_or_else(|e| {
                    panic!("seal segment in {}: {e}", self.dir.display())
                });
                let next = SegmentWriter::create(&self.dir, writer.end_offset(), self.cfg.index_every)
                    .unwrap_or_else(|e| panic!("roll segment in {}: {e}", self.dir.display()));
                *writer = next;
            }
            writer
                .append(msg)
                .unwrap_or_else(|e| panic!("append to {}: {e}", self.dir.display()));
        }
        // Hand the batch to the OS before it is acked: `kill -9` can no
        // longer lose it. An append that cannot reach the file must not
        // ack — panicking here keeps the broker honest (a broker that
        // cannot persist cannot accept).
        writer.flush().unwrap_or_else(|e| panic!("flush {}: {e}", self.dir.display()));
        if self.cfg.fsync == FsyncPolicy::PerBatch {
            writer.sync().unwrap_or_else(|e| panic!("fsync {}: {e}", self.dir.display()));
        } else {
            self.dirty.store(true, Ordering::Release);
        }
        self.end.store(writer.end_offset(), Ordering::Release);
    }

    fn end_offset(&self) -> u64 {
        self.end.load(Ordering::Acquire)
    }

    fn sync(&self) {
        let mut writer = self.state.lock().unwrap();
        if let Err(e) = writer.sync() {
            crate::log_warn!("storage", "fsync {} failed: {e}", self.dir.display());
        }
        self.dirty.store(false, Ordering::Release);
    }
}
