//! Segment files: the append-only on-disk form of one partition log.
//!
//! A partition directory holds a chain of segment files named by the
//! offset of their first record — `00000000000000000000.seg`,
//! `00000000000000004096.seg`, … — each with a sparse `.idx` sidecar
//! ([`super::index`]). A segment starts with a 20-byte header and is
//! followed by CRC-sealed records ([`super::record`]):
//!
//! | bytes | field                         |
//! |-------|-------------------------------|
//! | 8     | magic `RLSEG01\n`             |
//! | 8     | base offset (u64 LE)          |
//! | 4     | CRC-32 over magic + base      |
//!
//! # Recovery contract
//!
//! [`scan`] walks a segment from the header and stops at the first byte
//! run that fails to decode, reporting the valid prefix (its messages,
//! its byte length, and the per-record positions for index rebuilds) plus
//! a description of the damage. The *caller* decides what the damage
//! means: in the chain's **last** segment it is a torn tail — truncate to
//! the valid prefix and keep appending — while in any earlier segment it
//! would create an offset gap, so recovery refuses to open the partition.

use super::index::{self, IndexWriter};
use super::record::{self, RecordError};
use super::StorageError;
use crate::messaging::message::Message;
use crate::util::crc::crc32;
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

pub const SEG_MAGIC: &[u8; 8] = b"RLSEG01\n";
pub const SEG_HEADER: usize = 20;

/// Data-file name for a segment starting at `base`. Zero-padded so the
/// lexicographic directory order is the offset order.
pub fn seg_file_name(base: u64) -> String {
    format!("{base:020}.seg")
}

pub fn idx_file_name(base: u64) -> String {
    format!("{base:020}.idx")
}

/// Base offset encoded in a segment file name, if it is one.
pub fn parse_seg_file_name(name: &str) -> Option<u64> {
    let stem = name.strip_suffix(".seg")?;
    if stem.len() != 20 || !stem.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    stem.parse().ok()
}

pub fn header_bytes(base: u64) -> [u8; SEG_HEADER] {
    let mut h = [0u8; SEG_HEADER];
    h[0..8].copy_from_slice(SEG_MAGIC);
    h[8..16].copy_from_slice(&base.to_le_bytes());
    let crc = crc32(&h[0..16]);
    h[16..20].copy_from_slice(&crc.to_le_bytes());
    h
}

/// Validate a segment header against the base its file name promises.
fn check_header(buf: &[u8], expected_base: u64) -> Result<(), String> {
    if buf.len() < SEG_HEADER {
        return Err(format!("header truncated at {} bytes", buf.len()));
    }
    if &buf[0..8] != SEG_MAGIC {
        return Err("bad segment magic".to_string());
    }
    let base = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let stored = u32::from_le_bytes(buf[16..20].try_into().unwrap());
    if crc32(&buf[0..16]) != stored {
        return Err("segment header CRC mismatch".to_string());
    }
    if base != expected_base {
        return Err(format!("segment header base {base} != expected {expected_base}"));
    }
    Ok(())
}

/// Everything [`scan`] learned about one segment file.
pub struct ScanOutcome {
    /// Messages of the valid prefix, in offset order.
    pub messages: Vec<Message>,
    /// Byte length of the valid prefix (header + intact records). Zero
    /// when the header itself is damaged.
    pub valid_len: u64,
    /// Byte position of each valid record (for index rebuilds).
    pub positions: Vec<u64>,
    /// Why the scan stopped early, when the file does not end exactly at
    /// a record boundary.
    pub damage: Option<String>,
}

/// Scan a whole segment file, tolerating any tail damage.
pub fn scan(path: &Path, expected_base: u64) -> Result<ScanOutcome, StorageError> {
    let bytes = std::fs::read(path).map_err(StorageError::Io)?;
    if let Err(why) = check_header(&bytes, expected_base) {
        return Ok(ScanOutcome {
            messages: Vec::new(),
            valid_len: 0,
            positions: Vec::new(),
            damage: Some(format!("{}: {why}", path.display())),
        });
    }
    let mut messages = Vec::new();
    let mut positions = Vec::new();
    let mut at = SEG_HEADER;
    let mut damage = None;
    while at < bytes.len() {
        match record::decode(&bytes[at..]) {
            Ok((msg, used)) => {
                positions.push(at as u64);
                messages.push(msg);
                at += used;
            }
            Err(RecordError::Truncated) => {
                damage = Some(format!(
                    "{}: torn record at byte {at} ({} trailing bytes)",
                    path.display(),
                    bytes.len() - at
                ));
                break;
            }
            Err(RecordError::Corrupt(why)) => {
                damage = Some(format!("{}: corrupt record at byte {at}: {why}", path.display()));
                break;
            }
        }
    }
    Ok(ScanOutcome { messages, valid_len: at as u64, positions, damage })
}

/// Read up to `max` `(offset, message)` pairs starting at offset `from`,
/// seeking via the sparse index when it validates and falling back to a
/// header scan when it does not. Tail damage silently ends the read (only
/// the intact prefix is served) — recovery, not the read path, repairs
/// files.
pub fn read_from(
    dir: &Path,
    base: u64,
    from: u64,
    max: usize,
) -> Result<Vec<(u64, Message)>, StorageError> {
    let seg_path = dir.join(seg_file_name(base));
    let seg_len = std::fs::metadata(&seg_path).map_err(StorageError::Io)?.len();
    let mut f = File::open(&seg_path).map_err(StorageError::Io)?;
    let mut hdr = [0u8; SEG_HEADER];
    if f.read_exact(&mut hdr).is_err() || check_header(&hdr, base).is_err() {
        return Err(StorageError::Corrupt(format!(
            "{}: unreadable segment header",
            seg_path.display()
        )));
    }
    let rel_target = from.saturating_sub(base).min(u32::MAX as u64) as u32;
    let idx_entries =
        index::load(&dir.join(idx_file_name(base)), base, seg_len).unwrap_or_default();
    let (start_rel, start_pos) = index::lookup(&idx_entries, rel_target);

    // Trust-but-verify: if the very first record at the indexed position
    // fails to decode, the index lied — retry with a scan from the
    // header (the index is advisory, never load-bearing).
    match read_records(&mut f, base, start_rel as u64, start_pos, from, max) {
        Ok(out) => Ok(out),
        Err(()) if start_pos != SEG_HEADER as u64 => {
            read_records(&mut f, base, 0, SEG_HEADER as u64, from, max)
                .or(Ok(Vec::new()))
        }
        Err(()) => Ok(Vec::new()),
    }
}

/// Inner streaming read starting at byte `pos`, which should hold record
/// `base + rel`. `Err(())` means the **first** record at `pos` failed to
/// decode (an untrustworthy seek position); a failure after at least one
/// good record is tail damage and cleanly ends the read.
fn read_records(
    f: &mut File,
    base: u64,
    mut rel: u64,
    pos: u64,
    from: u64,
    max: usize,
) -> Result<Vec<(u64, Message)>, ()> {
    if f.seek(SeekFrom::Start(pos)).is_err() {
        return Err(());
    }
    let mut out = Vec::new();
    let mut first = true;
    loop {
        if out.len() >= max {
            return Ok(out);
        }
        let decoded = (|| {
            let mut head = [0u8; record::RECORD_HEADER];
            f.read_exact(&mut head).ok()?;
            let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
            if !(record::MIN_BODY..=record::MAX_BODY).contains(&len) {
                return None;
            }
            let stored = u32::from_le_bytes(head[4..8].try_into().unwrap());
            let mut body = vec![0u8; len];
            f.read_exact(&mut body).ok()?;
            if crc32(&body) != stored {
                return None;
            }
            record::decode_body(&body).ok()
        })();
        match decoded {
            Some(msg) => {
                let off = base + rel;
                if off >= from {
                    out.push((off, msg));
                }
                rel += 1;
                first = false;
            }
            // Clean EOF, torn tail, or a bad seek target.
            None if first => return Err(()),
            None => return Ok(out),
        }
    }
}

/// Append side of one segment file (plus its index sidecar).
pub struct SegmentWriter {
    file: BufWriter<File>,
    base: u64,
    records: u64,
    bytes: u64,
    index: IndexWriter,
    /// Record count at the last index entry (next entry once
    /// `records - last_indexed >= index_every`).
    last_indexed: u64,
    index_every: u64,
    scratch: Vec<u8>,
}

impl SegmentWriter {
    /// Create a fresh segment starting at offset `base` in `dir`.
    pub fn create(dir: &Path, base: u64, index_every: u64) -> std::io::Result<SegmentWriter> {
        let mut file = BufWriter::new(File::create(dir.join(seg_file_name(base)))?);
        file.write_all(&header_bytes(base))?;
        file.flush()?;
        file.get_ref().sync_data()?;
        let index = IndexWriter::create(&dir.join(idx_file_name(base)), base)?;
        Ok(SegmentWriter {
            file,
            base,
            records: 0,
            bytes: SEG_HEADER as u64,
            index,
            last_indexed: 0,
            index_every: index_every.max(1),
            scratch: Vec::with_capacity(4096),
        })
    }

    /// Reopen a recovered segment for appending after `records` intact
    /// records occupying `valid_len` bytes (recovery already truncated
    /// any damage and rewrote the index).
    pub fn open_end(
        dir: &Path,
        base: u64,
        valid_len: u64,
        records: u64,
        index_every: u64,
    ) -> std::io::Result<SegmentWriter> {
        let f = std::fs::OpenOptions::new().append(true).open(dir.join(seg_file_name(base)))?;
        debug_assert_eq!(f.metadata()?.len(), valid_len);
        let index = IndexWriter::append_to(&dir.join(idx_file_name(base)))?;
        Ok(SegmentWriter {
            file: BufWriter::new(f),
            base,
            records,
            bytes: valid_len,
            index,
            // Treat the reopen point as indexed so the stride resumes
            // cleanly; entries need not be evenly spaced to be useful.
            last_indexed: records,
            index_every: index_every.max(1),
            scratch: Vec::with_capacity(4096),
        })
    }

    /// Append one message (buffered — call [`SegmentWriter::flush`] or
    /// [`SegmentWriter::sync`] to push it down).
    pub fn append(&mut self, msg: &Message) -> std::io::Result<()> {
        if self.records == 0 || self.records - self.last_indexed >= self.index_every {
            // Index entry points at the record about to be written.
            self.index.push((self.records).min(u32::MAX as u64) as u32, self.bytes)?;
            self.last_indexed = self.records;
        }
        self.scratch.clear();
        let used = record::encode_into(&mut self.scratch, msg);
        self.file.write_all(&self.scratch)?;
        self.bytes += used as u64;
        self.records += 1;
        Ok(())
    }

    /// Push buffered bytes to the OS (kill -9 durable; not power-loss
    /// durable until [`SegmentWriter::sync`]).
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        self.index.flush()
    }

    /// Flush and fdatasync the data file (the index is advisory and is
    /// deliberately not fsynced — losing it costs a scan, not data).
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.flush()?;
        self.file.get_ref().sync_data()
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    /// Offset the next append will receive.
    pub fn end_offset(&self) -> u64 {
        self.base + self.records
    }

    pub fn len_bytes(&self) -> u64 {
        self.bytes
    }
}

/// Truncate a damaged segment to its valid prefix and rebuild its index.
/// A `valid_len` of zero (damaged header) resets the file to a fresh
/// header. Returns the record positions that survive.
pub fn truncate_to_valid(
    dir: &Path,
    base: u64,
    outcome: &ScanOutcome,
    index_every: u64,
) -> Result<(), StorageError> {
    let seg_path = dir.join(seg_file_name(base));
    let f = std::fs::OpenOptions::new().write(true).open(&seg_path).map_err(StorageError::Io)?;
    if outcome.valid_len == 0 {
        f.set_len(0).map_err(StorageError::Io)?;
        let mut w = BufWriter::new(&f);
        w.write_all(&header_bytes(base)).map_err(StorageError::Io)?;
        w.flush().map_err(StorageError::Io)?;
    } else {
        f.set_len(outcome.valid_len).map_err(StorageError::Io)?;
    }
    f.sync_data().map_err(StorageError::Io)?;
    let stride = index_every.max(1) as usize;
    let entries: Vec<(u32, u64)> = outcome
        .positions
        .iter()
        .enumerate()
        .filter(|(i, _)| i % stride == 0)
        .map(|(i, &pos)| (i as u32, pos))
        .collect();
    index::rewrite(&dir.join(idx_file_name(base)), base, &entries).map_err(StorageError::Io)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("rl_seg_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn msg(i: u64) -> Message {
        Message::new(Some(i), format!("payload-{i}").into_bytes(), i)
    }

    #[test]
    fn file_names_round_trip() {
        assert_eq!(seg_file_name(0), "00000000000000000000.seg");
        assert_eq!(parse_seg_file_name(&seg_file_name(0)), Some(0));
        assert_eq!(parse_seg_file_name(&seg_file_name(123456)), Some(123456));
        assert_eq!(parse_seg_file_name("junk.seg"), None);
        assert_eq!(parse_seg_file_name("00000000000000000000.idx"), None);
    }

    #[test]
    fn write_scan_round_trip() {
        let dir = tmp("rt");
        let mut w = SegmentWriter::create(&dir, 0, 8).unwrap();
        for i in 0..100 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        assert_eq!(w.end_offset(), 100);
        let out = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert!(out.damage.is_none());
        assert_eq!(out.messages.len(), 100);
        assert_eq!(out.positions.len(), 100);
        assert_eq!(out.valid_len, w.len_bytes());
        for (i, m) in out.messages.iter().enumerate() {
            assert_eq!(m, &msg(i as u64));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_from_uses_index_and_matches_scan() {
        let dir = tmp("read");
        let mut w = SegmentWriter::create(&dir, 500, 8).unwrap();
        for i in 0..200 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        let got = read_from(&dir, 500, 620, 50).unwrap();
        assert_eq!(got.len(), 50);
        assert_eq!(got[0].0, 620);
        assert_eq!(got[0].1, msg(120));
        assert_eq!(got[49].0, 669);
        // From before the base: everything from the start.
        let all = read_from(&dir, 500, 0, 1000).unwrap();
        assert_eq!(all.len(), 200);
        // Past the end: empty.
        assert!(read_from(&dir, 500, 700, 10).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_from_survives_corrupt_index() {
        let dir = tmp("badidx");
        let mut w = SegmentWriter::create(&dir, 0, 4).unwrap();
        for i in 0..50 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        // Poison the index with positions that point mid-record.
        index::rewrite(&dir.join(idx_file_name(0)), 0, &[(0, 21), (10, 37)]).unwrap();
        let got = read_from(&dir, 0, 10, 10).unwrap();
        assert_eq!(got.len(), 10);
        assert_eq!(got[0].0, 10);
        assert_eq!(got[0].1, msg(10));
        // Deleting the index entirely also works (plain scan).
        std::fs::remove_file(dir.join(idx_file_name(0))).unwrap();
        let got = read_from(&dir, 0, 45, 10).unwrap();
        assert_eq!(got.len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_scan_reports_valid_prefix() {
        let dir = tmp("torn");
        let mut w = SegmentWriter::create(&dir, 0, 8).unwrap();
        for i in 0..10 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        let full = std::fs::read(dir.join(seg_file_name(0))).unwrap();
        let out = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        let last_start = *out.positions.last().unwrap();
        // Cut inside the final record.
        std::fs::write(dir.join(seg_file_name(0)), &full[..last_start as usize + 3]).unwrap();
        let cut = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert_eq!(cut.messages.len(), 9);
        assert_eq!(cut.valid_len, last_start);
        assert!(cut.damage.is_some());
        // Truncate-to-valid then rescan: clean.
        truncate_to_valid(&dir, 0, &cut, 8).unwrap();
        let clean = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert_eq!(clean.messages.len(), 9);
        assert!(clean.damage.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn damaged_header_scan_yields_empty_valid_prefix() {
        let dir = tmp("hdr");
        let mut w = SegmentWriter::create(&dir, 0, 8).unwrap();
        w.append(&msg(0)).unwrap();
        w.sync().unwrap();
        let mut bytes = std::fs::read(dir.join(seg_file_name(0))).unwrap();
        bytes[3] ^= 0xFF;
        std::fs::write(dir.join(seg_file_name(0)), &bytes).unwrap();
        let out = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert_eq!(out.valid_len, 0);
        assert!(out.messages.is_empty());
        assert!(out.damage.is_some());
        // Repair resets to a fresh, scannable header.
        truncate_to_valid(&dir, 0, &out, 8).unwrap();
        let clean = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert!(clean.damage.is_none());
        assert!(clean.messages.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn base_mismatch_is_damage() {
        let dir = tmp("base");
        let mut w = SegmentWriter::create(&dir, 64, 8).unwrap();
        w.append(&msg(0)).unwrap();
        w.sync().unwrap();
        let out = scan(&dir.join(seg_file_name(64)), 65).unwrap();
        assert_eq!(out.valid_len, 0);
        assert!(out.damage.unwrap().contains("base"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_end_continues_appending() {
        let dir = tmp("reopen");
        let mut w = SegmentWriter::create(&dir, 0, 8).unwrap();
        for i in 0..5 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        let len = w.len_bytes();
        drop(w);
        let mut w = SegmentWriter::open_end(&dir, 0, len, 5, 8).unwrap();
        assert_eq!(w.end_offset(), 5);
        for i in 5..12 {
            w.append(&msg(i)).unwrap();
        }
        w.sync().unwrap();
        let out = scan(&dir.join(seg_file_name(0)), 0).unwrap();
        assert!(out.damage.is_none());
        assert_eq!(out.messages.len(), 12);
        assert_eq!(out.messages[11], msg(11));
        std::fs::remove_dir_all(&dir).ok();
    }
}
