//! [`MemStorage`]: an in-process [`Storage`] backend for deterministic
//! crash simulation.
//!
//! It models exactly the durability contract the disk backend provides,
//! without files or threads, so property tests can run thousands of
//! kill-at-arbitrary-point loops per second with reproducible seeds:
//!
//! - every append lands in `live` immediately (the disk backend's
//!   user-space flush — survives process death);
//! - `durable_len` trails `live` until a sync (the fdatasync boundary —
//!   survives power loss);
//! - [`MemStorage::crash`] simulates power loss: the un-synced suffix of
//!   every partition and any un-synced checkpoint update vanish;
//!   [`MemStorage::kill`] simulates `kill -9`: flushed data survives,
//!   only the policy-deferred checkpoint writes can lag.
//!
//! Under [`FsyncPolicy::PerBatch`] the two lengths never diverge, which
//! is the invariant the zero-acked-loss property asserts. No background
//! flusher thread exists here — `IntervalMs` simply behaves like `Off`
//! until someone calls [`Storage::sync`], keeping chaos fingerprints
//! deterministic.

use super::{CommitEntry, FsyncPolicy, PartitionStore, Storage, StorageConfig, StorageError, TopicMeta};
use crate::messaging::message::Message;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// In-memory storage with an explicit durable/volatile boundary.
pub struct MemStorage {
    cfg: StorageConfig,
    inner: Mutex<MemInner>,
}

#[derive(Default)]
struct MemInner {
    topics: BTreeMap<String, u32>,
    parts: BTreeMap<(String, usize), Arc<MemPartitionStore>>,
    /// Synced (power-loss durable) committed offsets.
    durable_commits: BTreeMap<(String, String, u32), u64>,
    /// Latest committed offsets, possibly not yet "synced".
    live_commits: BTreeMap<(String, String, u32), u64>,
}

impl MemStorage {
    pub fn new(cfg: StorageConfig) -> Arc<MemStorage> {
        Arc::new(MemStorage { cfg, inner: Mutex::new(MemInner::default()) })
    }

    /// Simulate power loss: every un-synced suffix disappears. The
    /// storage can then be re-opened by a fresh broker via
    /// [`crate::messaging::Broker::with_storage`].
    pub fn crash(&self) {
        let mut inner = self.inner.lock().unwrap();
        for part in inner.parts.values() {
            part.drop_unsynced();
        }
        inner.live_commits = inner.durable_commits.clone();
    }

    /// Simulate `kill -9`: flushed appends survive (they always do — the
    /// disk backend flushes per batch under every policy); commits that
    /// the policy deferred are promoted too, because the disk backend's
    /// `Drop` does not run on SIGKILL but its non-deferred checkpoint
    /// writes already hit the file. Only `IntervalMs`/`Off` commit
    /// deferral is lost.
    pub fn kill(&self) {
        let mut inner = self.inner.lock().unwrap();
        for part in inner.parts.values() {
            part.promote_all();
        }
        if self.cfg.fsync == FsyncPolicy::PerBatch {
            inner.durable_commits = inner.live_commits.clone();
        }
        inner.live_commits = inner.durable_commits.clone();
    }

    /// Test hook: promote only the commit table to durable, leaving
    /// partition appends volatile — models a checkpoint file that
    /// survived a power loss whose tail appends did not (the recovery
    /// path must clamp such commits to the recovered log end).
    pub fn sync_commits_only_for_test(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.durable_commits = inner.live_commits.clone();
    }

    /// Messages that would survive a crash right now, for assertions.
    pub fn durable_messages(&self, topic: &str, partition: usize) -> Vec<Message> {
        let inner = self.inner.lock().unwrap();
        match inner.parts.get(&(topic.to_string(), partition)) {
            Some(p) => p.durable_snapshot(),
            None => Vec::new(),
        }
    }
}

impl Storage for MemStorage {
    fn policy(&self) -> FsyncPolicy {
        self.cfg.fsync
    }

    fn load_topics(&self) -> Result<Vec<TopicMeta>, StorageError> {
        let inner = self.inner.lock().unwrap();
        Ok(inner
            .topics
            .iter()
            .map(|(name, partitions)| TopicMeta { name: name.clone(), partitions: *partitions as usize })
            .collect())
    }

    fn create_topic(&self, name: &str, partitions: usize) -> Result<(), StorageError> {
        let mut inner = self.inner.lock().unwrap();
        match inner.topics.get(name) {
            Some(existing) if *existing as usize != partitions => Err(StorageError::Corrupt(format!(
                "topic '{name}' persisted with {existing} partitions, asked for {partitions}"
            ))),
            Some(_) => Ok(()),
            None => {
                inner.topics.insert(name.to_string(), partitions as u32);
                Ok(())
            }
        }
    }

    fn open_partition(
        &self,
        topic: &str,
        partition: usize,
    ) -> Result<(Arc<dyn PartitionStore>, Vec<Message>), StorageError> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.topics.contains_key(topic) {
            return Err(StorageError::Corrupt(format!("topic '{topic}' not in the manifest")));
        }
        let part = inner
            .parts
            .entry((topic.to_string(), partition))
            .or_insert_with(|| {
                Arc::new(MemPartitionStore {
                    per_batch: self.cfg.fsync == FsyncPolicy::PerBatch,
                    inner: Mutex::new(MemPartInner::default()),
                    end: AtomicU64::new(0),
                })
            })
            .clone();
        let recovered = part.durable_snapshot();
        // Re-opening after a crash: the volatile suffix is already gone
        // (crash() dropped it); after kill() everything was promoted.
        part.reset_to_durable();
        Ok((part, recovered))
    }

    fn load_commits(&self) -> Vec<CommitEntry> {
        let inner = self.inner.lock().unwrap();
        inner
            .durable_commits
            .iter()
            .map(|((topic, group, partition), next)| CommitEntry {
                topic: topic.clone(),
                group: group.clone(),
                partition: *partition as usize,
                next: *next,
            })
            .collect()
    }

    fn checkpoint(&self, topic: &str, group: &str, entries: &[(usize, u64)]) {
        let mut inner = self.inner.lock().unwrap();
        for &(partition, next) in entries {
            let key = (topic.to_string(), group.to_string(), partition as u32);
            let live = inner.live_commits.entry(key.clone()).or_insert(0);
            if next > *live {
                *live = next;
            }
            if self.cfg.fsync == FsyncPolicy::PerBatch {
                let durable = inner.durable_commits.entry(key).or_insert(0);
                if next > *durable {
                    *durable = next;
                }
            }
        }
    }

    fn sync(&self) {
        let mut inner = self.inner.lock().unwrap();
        for part in inner.parts.values() {
            part.promote_all();
        }
        inner.durable_commits = inner.live_commits.clone();
    }
}

/// One partition's append log with a durable/volatile watermark.
pub struct MemPartitionStore {
    per_batch: bool,
    inner: Mutex<MemPartInner>,
    end: AtomicU64,
}

#[derive(Default)]
struct MemPartInner {
    messages: Vec<Message>,
    durable_len: usize,
}

impl MemPartitionStore {
    fn durable_snapshot(&self) -> Vec<Message> {
        let inner = self.inner.lock().unwrap();
        inner.messages[..inner.durable_len].to_vec()
    }

    fn drop_unsynced(&self) {
        let mut inner = self.inner.lock().unwrap();
        let durable = inner.durable_len;
        inner.messages.truncate(durable);
        self.end.store(durable as u64, Ordering::Release);
    }

    fn promote_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.durable_len = inner.messages.len();
    }

    fn reset_to_durable(&self) {
        let mut inner = self.inner.lock().unwrap();
        let durable = inner.durable_len;
        inner.messages.truncate(durable);
        self.end.store(durable as u64, Ordering::Release);
    }
}

impl PartitionStore for MemPartitionStore {
    fn append_batch(&self, msgs: &[Message]) {
        let mut inner = self.inner.lock().unwrap();
        inner.messages.extend_from_slice(msgs);
        if self.per_batch {
            inner.durable_len = inner.messages.len();
        }
        self.end.store(inner.messages.len() as u64, Ordering::Release);
    }

    fn end_offset(&self) -> u64 {
        self.end.load(Ordering::Acquire)
    }

    fn sync(&self) {
        self.promote_all();
    }
}
