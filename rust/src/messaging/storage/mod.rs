//! Durable storage for the broker: append-only segment files, sparse
//! offset indexes, and compacted checkpoint tables.
//!
//! # Shape
//!
//! | module         | provides                                            |
//! |----------------|-----------------------------------------------------|
//! | [`record`]     | length-prefixed, CRC-32-sealed message codec        |
//! | [`segment`]    | segment files, torn-tail scan/truncate, seek reads  |
//! | [`index`]      | advisory sparse offset index sidecars               |
//! | [`checkpoint`] | sealed offset/manifest tables with atomic rewrites  |
//! | [`disk`]       | [`DiskStorage`] — the real on-disk backend          |
//! | [`mem`]        | [`MemStorage`] — deterministic crash-sim backend    |
//!
//! # Durability contract
//!
//! [`PartitionStore::append_batch`] runs inside the partition's writer
//! mutex **before** the batch becomes visible to in-memory readers, so
//! the store's order is exactly the acked offset order. Every append is
//! flushed to the OS before it returns — acknowledged messages survive
//! `kill -9` under *any* [`FsyncPolicy`]. The policy chooses how far the
//! guarantee extends past the OS cache (power loss):
//!
//! | policy             | `kill -9`   | power loss                       |
//! |--------------------|-------------|----------------------------------|
//! | [`FsyncPolicy::PerBatch`]   | zero loss | zero loss (fdatasync per batch) |
//! | [`FsyncPolicy::IntervalMs`] | zero loss | ≤ interval of tail appends lost |
//! | [`FsyncPolicy::Off`]        | zero loss | un-synced tail lost             |
//!
//! Committed offsets are checkpointed monotonically; losing a checkpoint
//! update only ever causes **redelivery** (at-least-once still holds),
//! never loss.

pub mod checkpoint;
pub mod disk;
pub mod index;
pub mod mem;
pub mod record;
pub mod segment;

pub use disk::DiskStorage;
pub use mem::MemStorage;

use crate::messaging::message::Message;
use std::sync::Arc;

/// When appends and checkpoints are fdatasync'd past the OS cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fdatasync before every append batch / checkpoint returns.
    PerBatch,
    /// A background flusher fdatasyncs dirty state every `n` ms.
    IntervalMs(u64),
    /// Never fsync (except on segment roll and graceful shutdown).
    Off,
}

impl FsyncPolicy {
    /// Parse the CLI/config spelling: `per-batch`, `interval:<ms>`, `off`.
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        match s {
            "per-batch" | "batch" => Ok(FsyncPolicy::PerBatch),
            "off" | "none" => Ok(FsyncPolicy::Off),
            other => match other.strip_prefix("interval:").map(str::parse::<u64>) {
                Some(Ok(ms)) if ms > 0 => Ok(FsyncPolicy::IntervalMs(ms)),
                _ => Err(format!(
                    "bad fsync policy '{other}' (expected per-batch, interval:<ms>, or off)"
                )),
            },
        }
    }

    /// Stable label for logs and bench output.
    pub fn label(&self) -> String {
        match self {
            FsyncPolicy::PerBatch => "per-batch".to_string(),
            FsyncPolicy::IntervalMs(ms) => format!("interval:{ms}"),
            FsyncPolicy::Off => "off".to_string(),
        }
    }
}

/// Tuning knobs for a storage backend.
#[derive(Debug, Clone, Copy)]
pub struct StorageConfig {
    pub fsync: FsyncPolicy,
    /// Roll to a new segment once the current one reaches this size.
    pub segment_bytes: u64,
    /// Write one sparse index entry every this many records.
    pub index_every: u64,
}

impl Default for StorageConfig {
    fn default() -> StorageConfig {
        StorageConfig {
            fsync: FsyncPolicy::PerBatch,
            segment_bytes: 8 * 1024 * 1024,
            index_every: 64,
        }
    }
}

/// Why storage refused: an I/O failure, or on-disk state that cannot be
/// trusted (the open path refuses rather than serving a log with holes).
#[derive(Debug)]
pub enum StorageError {
    Io(std::io::Error),
    Corrupt(String),
}

impl std::fmt::Display for StorageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StorageError::Io(e) => write!(f, "storage I/O error: {e}"),
            StorageError::Corrupt(why) => write!(f, "storage corrupt: {why}"),
        }
    }
}

impl std::error::Error for StorageError {}

impl From<std::io::Error> for StorageError {
    fn from(e: std::io::Error) -> StorageError {
        StorageError::Io(e)
    }
}

/// A persisted topic, as recovered from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopicMeta {
    pub name: String,
    pub partitions: usize,
}

/// One recovered committed offset: group `group` on `topic[partition]`
/// resumes consuming at `next`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommitEntry {
    pub topic: String,
    pub group: String,
    pub partition: usize,
    pub next: u64,
}

/// Append side of one partition, driven by
/// [`PartitionLog`](crate::messaging::partition::PartitionLog) under its
/// writer mutex.
pub trait PartitionStore: Send + Sync {
    /// Persist a batch. Called before the batch is published to readers;
    /// must not return until the batch would survive `kill -9`.
    fn append_batch(&self, msgs: &[Message]);
    /// Offsets below this are persisted.
    fn end_offset(&self) -> u64;
    /// Force everything down to power-loss durability.
    fn sync(&self);
}

/// A storage backend: topic manifest, per-partition append logs, and the
/// committed-offset checkpoint table.
pub trait Storage: Send + Sync {
    fn policy(&self) -> FsyncPolicy;
    /// Topics persisted by an earlier run, for recovery.
    fn load_topics(&self) -> Result<Vec<TopicMeta>, StorageError>;
    /// Persist a topic's existence (idempotent; partition-count mismatch
    /// with persisted state is an error).
    fn create_topic(&self, name: &str, partitions: usize) -> Result<(), StorageError>;
    /// Open one partition's store and return the recovered messages in
    /// offset order (torn tails already truncated away).
    fn open_partition(
        &self,
        topic: &str,
        partition: usize,
    ) -> Result<(Arc<dyn PartitionStore>, Vec<Message>), StorageError>;
    /// Recovered committed offsets (empty after checkpoint corruption —
    /// the broker redelivers from zero, preserving at-least-once).
    fn load_commits(&self) -> Vec<CommitEntry>;
    /// Record committed offsets for a group; values only move forward.
    fn checkpoint(&self, topic: &str, group: &str, entries: &[(usize, u64)]);
    /// Push all dirty state to power-loss durability.
    fn sync(&self);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsync_policy_parse_and_label() {
        assert_eq!(FsyncPolicy::parse("per-batch"), Ok(FsyncPolicy::PerBatch));
        assert_eq!(FsyncPolicy::parse("batch"), Ok(FsyncPolicy::PerBatch));
        assert_eq!(FsyncPolicy::parse("off"), Ok(FsyncPolicy::Off));
        assert_eq!(FsyncPolicy::parse("interval:25"), Ok(FsyncPolicy::IntervalMs(25)));
        assert!(FsyncPolicy::parse("interval:0").is_err());
        assert!(FsyncPolicy::parse("interval:abc").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        for p in [FsyncPolicy::PerBatch, FsyncPolicy::IntervalMs(25), FsyncPolicy::Off] {
            assert_eq!(FsyncPolicy::parse(&p.label()), Ok(p), "label round-trips");
        }
    }

    #[test]
    fn mem_storage_crash_drops_unsynced_only() {
        let cfg = StorageConfig { fsync: FsyncPolicy::Off, ..StorageConfig::default() };
        let storage = MemStorage::new(cfg);
        storage.create_topic("t", 1).unwrap();
        let (part, recovered) = storage.open_partition("t", 0).unwrap();
        assert!(recovered.is_empty());
        let msgs: Vec<Message> = (0..10).map(|i| Message::new(None, vec![i as u8], i)).collect();
        part.append_batch(&msgs[..6]);
        storage.sync();
        part.append_batch(&msgs[6..]);
        assert_eq!(part.end_offset(), 10);
        storage.crash();
        let (part2, recovered) = storage.open_partition("t", 0).unwrap();
        assert_eq!(recovered, msgs[..6].to_vec(), "synced prefix survives power loss");
        assert_eq!(part2.end_offset(), 6);
    }

    #[test]
    fn mem_storage_kill_keeps_everything_appended() {
        let cfg = StorageConfig { fsync: FsyncPolicy::Off, ..StorageConfig::default() };
        let storage = MemStorage::new(cfg);
        storage.create_topic("t", 1).unwrap();
        let (part, _) = storage.open_partition("t", 0).unwrap();
        let msgs: Vec<Message> = (0..5).map(|i| Message::new(None, vec![i as u8], i)).collect();
        part.append_batch(&msgs);
        storage.kill();
        let (_, recovered) = storage.open_partition("t", 0).unwrap();
        assert_eq!(recovered, msgs, "kill -9 never loses flushed appends");
    }

    #[test]
    fn mem_storage_commits_respect_policy() {
        let per_batch = MemStorage::new(StorageConfig::default());
        per_batch.create_topic("t", 1).unwrap();
        per_batch.checkpoint("t", "g", &[(0, 42)]);
        per_batch.crash();
        assert_eq!(per_batch.load_commits().len(), 1, "per-batch commit survives crash");

        let off = MemStorage::new(StorageConfig { fsync: FsyncPolicy::Off, ..StorageConfig::default() });
        off.create_topic("t", 1).unwrap();
        off.checkpoint("t", "g", &[(0, 42)]);
        off.crash();
        assert!(off.load_commits().is_empty(), "unsynced commit lost to power loss");
        off.checkpoint("t", "g", &[(0, 7)]);
        off.sync();
        off.crash();
        assert_eq!(off.load_commits(), vec![CommitEntry {
            topic: "t".into(),
            group: "g".into(),
            partition: 0,
            next: 7,
        }]);
    }

    #[test]
    fn checkpoint_is_monotonic() {
        let storage = MemStorage::new(StorageConfig::default());
        storage.create_topic("t", 1).unwrap();
        storage.checkpoint("t", "g", &[(0, 42)]);
        storage.checkpoint("t", "g", &[(0, 17)]); // stale commit must not regress
        let commits = storage.load_commits();
        assert_eq!(commits.len(), 1);
        assert_eq!(commits[0].next, 42);
    }
}
