//! Consumer-group coordination: membership, partition assignment,
//! positions and committed offsets.
//!
//! Invariants (checked by property tests in `rust/tests/broker_semantics.rs`):
//!
//! 1. within a group, every partition is owned by **at most one** member;
//! 2. when the group has ≥1 member, **every** partition is owned;
//! 3. members beyond the partition count own nothing (they are idle — the
//!    Liquid task cap);
//! 4. positions only move forward between rebalances, and reset to the
//!    committed offset on rebalance (at-least-once delivery).

use std::collections::{BTreeSet, HashMap};

/// Opaque consumer-group member identity.
pub type MemberId = u64;

/// State of one consumer group on one topic.
pub struct GroupState {
    members: BTreeSet<MemberId>,
    /// member → owned partitions (round-robin over sorted members, so the
    /// assignment is deterministic for a given membership).
    assignment: HashMap<MemberId, Vec<usize>>,
    /// Rebalance generation (bumped on every membership change).
    generation: u64,
    /// partition → next offset to read. Valid only between rebalances.
    positions: Vec<u64>,
    /// partition → committed offset (the next offset a recovering consumer
    /// should read).
    committed: Vec<u64>,
    partitions: usize,
}

impl GroupState {
    pub fn new(partitions: usize) -> Self {
        GroupState {
            members: BTreeSet::new(),
            assignment: HashMap::new(),
            generation: 0,
            positions: vec![0; partitions],
            committed: vec![0; partitions],
            partitions,
        }
    }

    /// Add a member and rebalance. Idempotent for an existing member.
    pub fn join(&mut self, member: MemberId) {
        if self.members.insert(member) {
            self.rebalance();
        }
    }

    /// Remove a member and rebalance. No-op for an unknown member.
    pub fn leave(&mut self, member: MemberId) {
        if self.members.remove(&member) {
            self.rebalance();
        }
    }

    fn rebalance(&mut self) {
        self.generation += 1;
        self.assignment.clear();
        let members: Vec<MemberId> = self.members.iter().copied().collect();
        if members.is_empty() {
            // Nothing assigned; positions will be re-seeded on next join.
            return;
        }
        for p in 0..self.partitions {
            let owner = members[p % members.len()];
            self.assignment.entry(owner).or_default().push(p);
        }
        // At-least-once: unread-but-uncommitted progress is discarded.
        self.positions.copy_from_slice(&self.committed);
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Partitions owned by `member` (empty for idle/unknown members).
    pub fn assigned(&self, member: MemberId) -> &[usize] {
        self.assignment.get(&member).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Current read position of a partition.
    pub fn position(&self, partition: usize) -> u64 {
        self.positions[partition]
    }

    /// Advance the read position. Positions are monotonic between
    /// rebalances: a stale advance (at or below the current position, as
    /// a racing poll of the same member can produce now that partition
    /// reads happen outside the coordinator lock) is ignored — the racer
    /// merely redelivers, which at-least-once allows.
    pub fn advance(&mut self, partition: usize, to: u64) {
        if to > self.positions[partition] {
            self.positions[partition] = to;
        }
    }

    /// Commit `next` as the restart offset for `partition`. Commits are
    /// monotonic: a stale commit (lower than the current one) is ignored.
    /// Returns how far the committed offset moved, so the broker can
    /// mirror the total into its lock-free lag counter.
    pub fn commit(&mut self, partition: usize, next: u64) -> u64 {
        let cur = self.committed[partition];
        if next > cur {
            self.committed[partition] = next;
            next - cur
        } else {
            0
        }
    }

    pub fn committed(&self, partition: usize) -> u64 {
        self.committed[partition]
    }

    /// Check invariants 1–3 (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut owned = vec![0usize; self.partitions];
        for (m, parts) in &self.assignment {
            if !self.members.contains(m) {
                return Err(format!("assignment for non-member {m}"));
            }
            for &p in parts {
                owned[p] += 1;
            }
        }
        for (p, &n) in owned.iter().enumerate() {
            if n > 1 {
                return Err(format!("partition {p} owned by {n} members"));
            }
            if n == 0 && !self.members.is_empty() {
                return Err(format!("partition {p} unowned with {} members", self.members.len()));
            }
        }
        let active = self.assignment.values().filter(|v| !v.is_empty()).count();
        if active > self.partitions {
            return Err(format!("{active} active members > {} partitions", self.partitions));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_member_owns_all() {
        let mut g = GroupState::new(3);
        g.join(10);
        assert_eq!(g.assigned(10), &[0, 1, 2]);
        g.check_invariants().unwrap();
    }

    #[test]
    fn members_beyond_partitions_idle() {
        let mut g = GroupState::new(3);
        for m in 0..6 {
            g.join(m);
        }
        let active = (0..6).filter(|&m| !g.assigned(m).is_empty()).count();
        assert_eq!(active, 3, "only as many active consumers as partitions");
        g.check_invariants().unwrap();
    }

    #[test]
    fn leave_triggers_reassignment() {
        let mut g = GroupState::new(4);
        g.join(1);
        g.join(2);
        let gen = g.generation();
        g.leave(1);
        assert_eq!(g.generation(), gen + 1);
        assert_eq!(g.assigned(2), &[0, 1, 2, 3]);
        assert!(g.assigned(1).is_empty());
        g.check_invariants().unwrap();
    }

    #[test]
    fn rebalance_resets_position_to_committed() {
        let mut g = GroupState::new(1);
        g.join(1);
        g.advance(0, 50);
        g.commit(0, 30);
        assert_eq!(g.position(0), 50);
        g.join(2); // rebalance
        assert_eq!(g.position(0), 30, "uncommitted progress discarded");
    }

    #[test]
    fn commits_monotonic() {
        let mut g = GroupState::new(1);
        g.join(1);
        g.commit(0, 10);
        g.commit(0, 5);
        assert_eq!(g.committed(0), 10);
        g.commit(0, 20);
        assert_eq!(g.committed(0), 20);
    }

    #[test]
    fn idempotent_join() {
        let mut g = GroupState::new(2);
        g.join(1);
        let gen = g.generation();
        g.join(1);
        assert_eq!(g.generation(), gen, "re-join of same member is a no-op");
    }
}
