//! Broker client abstraction: one surface, local or remote.
//!
//! [`BrokerClient`] is the narrow waist between the layers above the
//! messaging layer (vml, processing, the experiment runner) and a broker.
//! The in-process [`Broker`] implements it directly,
//! [`RemoteBroker`](crate::transport::RemoteBroker) implements the same
//! trait over a wire [`Connection`](crate::transport::Connection), and
//! [`ClusterClient`](crate::transport::ClusterClient) implements it over
//! a whole *cluster* of brokers (routing each publish to the partition's
//! HRW owner and draining every node) — so a pipeline runs unchanged
//! whether its broker lives in this process, behind a socket on another
//! node, or spread across three.
//!
//! The trait is deliberately *batch-first and narrow*: only the calls the
//! pipeline actually makes (create, publish a batch, subscribe, lag
//! probes) cross it, which is also exactly the frame vocabulary of the
//! wire protocol ([`transport::frame`](crate::transport::frame)). Local
//! extras (raw partition reads, invariant hooks, member counts) stay on
//! the concrete [`Broker`].

use super::broker::{Broker, Consumer, PolledBatch};
use super::message::Message;
use std::sync::Arc;

/// A consumer-group membership, local or remote.
///
/// Mirrors the data-plane surface of [`Consumer`]: batch polling with
/// generation-fenced batch commits (see the [`messaging`](crate::messaging)
/// module docs for the at-least-once contract). Dropping the handle
/// without [`ConsumerClient::close`] mimics a crash: the group rebalances
/// and uncommitted offsets are redelivered.
pub trait ConsumerClient: Send {
    /// Partitions this member currently owns.
    fn assignment(&self) -> Vec<usize>;

    /// Poll up to `max` messages with commit bookkeeping. Non-blocking;
    /// may return an empty batch (remote implementations also return an
    /// empty batch on a transport hiccup — the caller simply re-polls,
    /// which is the at-least-once answer).
    fn poll_batch(&self, max: usize) -> PolledBatch;

    /// Commit `next` (the next offset to read) for `partition`.
    fn commit(&self, partition: usize, next: u64);

    /// Commit every watermark of `batch` under one coordinator lock;
    /// `false` means the commit was fenced (rebalance since poll) or lost
    /// in transit — either way nothing was committed and the batch's
    /// offsets will be redelivered.
    fn commit_batch(&self, batch: &PolledBatch) -> bool;

    /// Leave the group gracefully.
    fn close(self: Box<Self>);
}

/// A broker endpoint, local or remote.
pub trait BrokerClient: Send + Sync {
    /// Create a topic (idempotent for an existing topic with the same
    /// partition count).
    fn create_topic(&self, topic: &str, partitions: usize);

    /// Partition count of `topic`; `None` means exactly "the topic does
    /// not exist". Remote implementations crash on an unreachable broker
    /// rather than conflate it with nonexistence (callers size consumer
    /// groups off this answer).
    fn partition_count(&self, topic: &str) -> Option<usize>;

    /// Publish a batch; returns `(partition, offset)` per message, in
    /// input order. Keyed messages land on their key's partition and
    /// input order is preserved within every partition (see
    /// [`Topic::publish_batch`](crate::messaging::broker::Topic::publish_batch)).
    fn publish_batch(&self, topic: &str, msgs: Vec<Message>) -> Vec<(usize, u64)>;

    /// Join `group` on `topic`, returning a membership handle.
    fn subscribe(&self, topic: &str, group: &str) -> Box<dyn ConsumerClient>;

    /// Published-minus-committed lag of one group (elastic signal).
    fn group_lag(&self, topic: &str, group: &str) -> u64;

    /// Sum of every group's lag on every topic (drain watermark). Remote
    /// clients return `u64::MAX` when the probe cannot reach the broker,
    /// so a transport failure can never read as "drained".
    fn total_lag(&self) -> u64;
}

/// The shared handle the pipeline layers hold.
pub type SharedBrokerClient = Arc<dyn BrokerClient>;

impl ConsumerClient for Consumer {
    fn assignment(&self) -> Vec<usize> {
        Consumer::assignment(self)
    }

    fn poll_batch(&self, max: usize) -> PolledBatch {
        Consumer::poll_batch(self, max)
    }

    fn commit(&self, partition: usize, next: u64) {
        Consumer::commit(self, partition, next)
    }

    fn commit_batch(&self, batch: &PolledBatch) -> bool {
        Consumer::commit_batch(self, batch)
    }

    fn close(self: Box<Self>) {
        Consumer::close(*self)
    }
}

impl BrokerClient for Broker {
    fn create_topic(&self, topic: &str, partitions: usize) {
        let _ = Broker::create_topic(self, topic, partitions);
    }

    fn partition_count(&self, topic: &str) -> Option<usize> {
        self.topic(topic).map(|t| t.partition_count())
    }

    fn publish_batch(&self, topic: &str, msgs: Vec<Message>) -> Vec<(usize, u64)> {
        self.topic(topic)
            .unwrap_or_else(|| panic!("unknown topic '{topic}'"))
            .publish_batch(msgs)
    }

    fn subscribe(&self, topic: &str, group: &str) -> Box<dyn ConsumerClient> {
        Box::new(Broker::subscribe(self, topic, group))
    }

    fn group_lag(&self, topic: &str, group: &str) -> u64 {
        Broker::group_lag(self, topic, group)
    }

    fn total_lag(&self) -> u64 {
        Broker::total_lag(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_broker_through_client_trait() {
        let broker = Broker::new();
        let client: SharedBrokerClient = broker.clone();
        client.create_topic("t", 2);
        client.create_topic("t", 2); // idempotent
        assert_eq!(client.partition_count("t"), Some(2));
        assert_eq!(client.partition_count("missing"), None);

        let placed = client
            .publish_batch("t", (0..10u8).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(placed.len(), 10);
        assert_eq!(client.group_lag("t", "g"), 10);

        let consumer = client.subscribe("t", "g");
        assert_eq!(consumer.assignment().len(), 2);
        let batch = consumer.poll_batch(100);
        assert_eq!(batch.len(), 10);
        assert!(consumer.commit_batch(&batch));
        assert_eq!(client.group_lag("t", "g"), 0);
        assert_eq!(client.total_lag(), 0);
        consumer.close();
        assert_eq!(broker.group_members("t", "g"), 0, "close left the group");
    }

    #[test]
    fn dropping_client_consumer_mimics_crash() {
        let broker = Broker::new();
        let client: SharedBrokerClient = broker.clone();
        client.create_topic("t", 1);
        client.publish_batch("t", (0..5u8).map(|i| Message::new(None, vec![i], 0)).collect());
        let consumer = client.subscribe("t", "g");
        assert_eq!(consumer.poll_batch(5).len(), 5);
        drop(consumer); // crash: no commit
        let again = client.subscribe("t", "g");
        assert_eq!(again.poll_batch(5).len(), 5, "uncommitted batch redelivered");
        again.close();
    }
}
