//! A partition: an append-only, offset-indexed message log.
//!
//! # Segmented, lock-free-read layout
//!
//! The log is a forward-linked chain of fixed-capacity **segments** of
//! [`SEGMENT_SLOTS`] message slots each. Offsets are dense and start at 0;
//! offset `o` lives in slot `o - base` of the segment whose `base` covers
//! it. Segments are only ever appended, never resized or removed, so a
//! message's slot address is stable for the life of the log — appends
//! never reallocate, and a reader is never invalidated by a concurrent
//! append (the `RwLock<Vec<_>>` this replaced memcpy'd the whole log on
//! every regrow, stalling all readers behind the write lock).
//!
//! # Tail-publish protocol
//!
//! - **Appends** serialize on a small writer mutex (writers only contend
//!   with other writers). The holder writes messages into unpublished
//!   slots, links a fresh segment when the current one fills, and then
//!   *publishes* the batch with one release-store of the `tail` counter.
//! - **Reads take no lock at all**: an acquire-load of `tail` makes every
//!   slot write and segment link below it visible, so readers walk the
//!   committed prefix directly. `read`/`end_offset` cost the same whether
//!   zero or a thousand other threads are polling.
//!
//! Slots at or above `tail` are only touched by the writer holding the
//! mutex; slots below `tail` are immutable. That single invariant is what
//! the `unsafe` blocks below rely on.
//!
//! # Durability hook
//!
//! A log may carry an attached [`PartitionStore`]. Appends then persist
//! the batch **first** — still under the writer mutex, still before the
//! tail publish — so disk order, memory order, and the offsets consumers
//! are acked against are always the same sequence. A log without a store
//! behaves exactly as before (the store check is one `OnceLock` load).

use super::message::Message;
use super::storage::PartitionStore;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Messages per segment. Large enough that chain hops are rare on batch
/// reads, small enough that a fresh partition costs ~one page of slots.
pub const SEGMENT_SLOTS: usize = 1024;

/// One fixed-capacity run of message slots.
///
/// `slots[i]` holds offset `base + i`. A slot is written exactly once (by
/// the appender that claimed it, under the writer mutex) and becomes
/// immutable once the log's `tail` counter passes it.
struct Segment {
    /// Offset of `slots[0]`.
    base: u64,
    slots: Box<[UnsafeCell<MaybeUninit<Message>>]>,
    /// The following segment (set once, by the writer that filled this
    /// one). Readers traverse it only for offsets below the published
    /// tail, which the tail's release/acquire edge makes safe.
    next: OnceLock<Arc<Segment>>,
    /// How many leading slots hold initialized messages — only consulted
    /// on drop (the happens-before edge is `Arc`'s refcount teardown).
    init: AtomicUsize,
}

// SAFETY: the `UnsafeCell` slots are written only by the single thread
// holding the log's writer mutex, and only while the slot is above the
// published tail; every other access (reads below the tail, drop) sees
// the slot after a release/acquire or refcount synchronization point.
unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

impl Segment {
    fn new(base: u64) -> Self {
        Segment {
            base,
            slots: (0..SEGMENT_SLOTS).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
            next: OnceLock::new(),
            init: AtomicUsize::new(0),
        }
    }
}

impl Drop for Segment {
    fn drop(&mut self) {
        let n = *self.init.get_mut();
        for slot in self.slots.iter_mut().take(n) {
            // SAFETY: the writer initialized exactly the first `init`
            // slots; `&mut self` proves no reader can observe them now.
            unsafe { slot.get_mut().assume_init_drop() };
        }
    }
}

/// Append-only log with lock-free reads (see the module docs for the
/// segment layout and the tail-publish protocol).
pub struct PartitionLog {
    /// First segment (base 0). Owns the whole chain via `Segment::next`.
    head: Arc<Segment>,
    /// The segment currently being filled — a cursor into the chain so
    /// near-tail readers and the appender skip the head walk. Always
    /// points at a segment kept alive by the chain.
    tail_seg: AtomicPtr<Segment>,
    /// First offset past the published prefix. The release-store here is
    /// what hands finished slots over to readers.
    tail: AtomicU64,
    /// Serializes appenders (and only appenders) — never held by readers.
    writer: Mutex<()>,
    /// Durable backing, if any. Set once during recovery wiring; appends
    /// write through it before publishing to readers.
    store: OnceLock<Arc<dyn PartitionStore>>,
}

impl PartitionLog {
    pub fn new() -> Self {
        let head = Arc::new(Segment::new(0));
        let tail_seg = AtomicPtr::new(Arc::as_ptr(&head) as *mut Segment);
        PartitionLog {
            head,
            tail_seg,
            tail: AtomicU64::new(0),
            writer: Mutex::new(()),
            store: OnceLock::new(),
        }
    }

    /// Attach a durable store. Called once during recovery wiring, after
    /// [`PartitionLog::restore`] replayed the store's messages, so the
    /// two ends must already agree — from here on every append writes
    /// through the store before it is published.
    pub fn attach_store(&self, store: Arc<dyn PartitionStore>) {
        let _guard = self.writer.lock().unwrap();
        assert_eq!(
            store.end_offset(),
            self.tail.load(Ordering::Relaxed),
            "store and log must agree on the end offset before attachment"
        );
        assert!(self.store.set(store).is_ok(), "store attached twice");
    }

    /// Replay recovered messages into a log that has no store attached
    /// yet (recovery only — the store already holds these records).
    pub fn restore(&self, msgs: Vec<Message>) {
        assert!(self.store.get().is_none(), "restore must precede attach_store");
        if msgs.is_empty() {
            return;
        }
        let _guard = self.writer.lock().unwrap();
        let base = self.tail.load(Ordering::Relaxed);
        let n = msgs.len() as u64;
        self.write_slots_locked(base, msgs.into_iter());
        self.tail.store(base + n, Ordering::Release);
    }

    /// Append one message, returning its offset.
    pub fn append(&self, msg: Message) -> u64 {
        let _guard = self.writer.lock().unwrap();
        // Only the mutex holder stores `tail`, so this read is exact.
        let base = self.tail.load(Ordering::Relaxed);
        if let Some(store) = self.store.get() {
            // Persist before publish: a message a reader can see is
            // already on disk (see the module docs).
            store.append_batch(std::slice::from_ref(&msg));
        }
        self.write_slots_locked(base, std::iter::once(msg));
        self.tail.store(base + 1, Ordering::Release);
        base
    }

    /// Append a whole batch under one writer-mutex acquisition, returning
    /// the offset of the first appended message (the batch occupies the
    /// dense range `base..base + msgs.len()`, in input order). The batch
    /// becomes visible to readers atomically: one tail publish covers all
    /// of it. For an empty batch the current end offset is returned and
    /// nothing is written.
    pub fn append_batch(&self, msgs: Vec<Message>) -> u64 {
        let _guard = self.writer.lock().unwrap();
        let base = self.tail.load(Ordering::Relaxed);
        if msgs.is_empty() {
            return base;
        }
        if let Some(store) = self.store.get() {
            store.append_batch(&msgs);
        }
        let n = msgs.len() as u64;
        self.write_slots_locked(base, msgs.into_iter());
        self.tail.store(base + n, Ordering::Release);
        base
    }

    /// Write `msgs` into the slots starting at `base`. Caller holds the
    /// writer mutex and publishes the tail afterwards.
    fn write_slots_locked<I>(&self, base: u64, msgs: I)
    where
        I: Iterator<Item = Message>,
    {
        // SAFETY: `tail_seg` points into the chain owned by `self.head`,
        // and segments are never unlinked while `&self` is alive.
        let mut seg: &Segment = unsafe { &*self.tail_seg.load(Ordering::Relaxed) };
        for (i, msg) in msgs.enumerate() {
            let off = base + i as u64;
            let mut idx = (off - seg.base) as usize;
            if idx == SEGMENT_SLOTS {
                // Current segment is full: link its successor and move the
                // tail-segment cursor forward. Readers may only follow the
                // link for offsets below the published tail, all of which
                // stay in earlier segments until the store below.
                let next = Arc::new(Segment::new(off));
                let ptr = Arc::as_ptr(&next) as *mut Segment;
                assert!(seg.next.set(next).is_ok(), "tail segment linked twice");
                self.tail_seg.store(ptr, Ordering::Release);
                // SAFETY: the chain now owns the segment behind `ptr`.
                seg = unsafe { &*ptr };
                idx = 0;
            }
            // SAFETY: `off >= tail`, so no reader touches this slot yet,
            // and the writer mutex excludes every other appender.
            unsafe { seg.slots[idx].get().write(MaybeUninit::new(msg)) };
            seg.init.store(idx + 1, Ordering::Relaxed);
        }
        // The caller's release-store of `tail` publishes these writes:
        // everything above happens-before any reader's acquire-load that
        // observes the new tail.
    }

    /// First offset *past* the log end (== number of messages).
    pub fn end_offset(&self) -> u64 {
        self.tail.load(Ordering::Acquire)
    }

    /// Read up to `max` messages starting at `from` (clamped to log end).
    /// Returns `(offset, message)` pairs; message clones are refcount
    /// bumps. Takes no lock: one acquire-load of the tail, then direct
    /// slot reads of the committed prefix.
    pub fn read(&self, from: u64, max: usize) -> Vec<(u64, Message)> {
        let end = self.tail.load(Ordering::Acquire);
        if from >= end || max == 0 {
            return Vec::new();
        }
        let stop = from.saturating_add(max as u64).min(end);
        let mut out = Vec::with_capacity((stop - from) as usize);
        let mut seg = self.seek(from);
        for off in from..stop {
            let mut idx = (off - seg.base) as usize;
            if idx == SEGMENT_SLOTS {
                seg = seg.next.get().expect("offsets below the tail are linked").as_ref();
                idx = 0;
            }
            // SAFETY: `off < end`, and the acquire-load of `tail` above
            // synchronized with the release-store that published `off`'s
            // slot write; published slots are immutable.
            let msg = unsafe { (*seg.slots[idx].get()).assume_init_ref().clone() };
            out.push((off, msg));
        }
        out
    }

    /// Segment containing `offset`. Callers must have observed a
    /// published tail greater than `offset`.
    fn seek(&self, offset: u64) -> &Segment {
        // Fast path: consumers overwhelmingly read near the tail.
        // SAFETY: the cursor always points at a chain-owned segment; the
        // acquire-load pairs with the release-store in `append_iter` so
        // the segment's fields are visible.
        let tail_seg: &Segment = unsafe { &*self.tail_seg.load(Ordering::Acquire) };
        if offset >= tail_seg.base {
            return tail_seg;
        }
        let mut seg: &Segment = &self.head;
        while offset >= seg.base + SEGMENT_SLOTS as u64 {
            seg = seg.next.get().expect("offsets below the tail are linked").as_ref();
        }
        seg
    }
}

impl Drop for PartitionLog {
    fn drop(&mut self) {
        // Unlink the chain iteratively so a long log can't overflow the
        // stack with recursive `Arc<Segment>` drops.
        let mut cur = Arc::get_mut(&mut self.head).and_then(|s| s.next.take());
        while let Some(mut seg) = cur {
            cur = Arc::get_mut(&mut seg).and_then(|s| s.next.take());
        }
    }
}

impl Default for PartitionLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Records every appended message; end offset tracks the log's.
    struct RecordingStore {
        seen: Mutex<Vec<Message>>,
    }

    impl PartitionStore for RecordingStore {
        fn append_batch(&self, msgs: &[Message]) {
            self.seen.lock().unwrap().extend_from_slice(msgs);
        }
        fn end_offset(&self) -> u64 {
            self.seen.lock().unwrap().len() as u64
        }
        fn sync(&self) {}
    }

    #[test]
    fn attached_store_sees_every_append_in_offset_order() {
        let log = PartitionLog::new();
        let store = Arc::new(RecordingStore { seen: Mutex::new(Vec::new()) });
        log.attach_store(store.clone());
        log.append(Message::from_str("a"));
        log.append_batch(vec![Message::from_str("b"), Message::from_str("c")]);
        log.append_batch(Vec::new()); // empty batch never reaches the store
        let seen = store.seen.lock().unwrap();
        let texts: Vec<_> = seen.iter().map(|m| m.payload_str().unwrap()).collect();
        assert_eq!(texts, ["a", "b", "c"], "store order == offset order");
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn restore_then_attach_resumes_offsets() {
        let log = PartitionLog::new();
        let recovered = vec![Message::from_str("r0"), Message::from_str("r1")];
        log.restore(recovered.clone());
        assert_eq!(log.end_offset(), 2);
        assert_eq!(log.read(0, 10).len(), 2);
        let store = Arc::new(RecordingStore { seen: Mutex::new(recovered) });
        log.attach_store(store.clone());
        assert_eq!(log.append(Message::from_str("new")), 2, "appends continue past recovery");
        assert_eq!(store.seen.lock().unwrap().len(), 3);
    }

    #[test]
    #[should_panic(expected = "agree on the end offset")]
    fn attach_store_rejects_offset_mismatch() {
        let log = PartitionLog::new();
        log.restore(vec![Message::from_str("x")]);
        log.attach_store(Arc::new(RecordingStore { seen: Mutex::new(Vec::new()) }));
    }

    #[test]
    fn append_assigns_dense_offsets() {
        let log = PartitionLog::new();
        assert_eq!(log.append(Message::from_str("a")), 0);
        assert_eq!(log.append(Message::from_str("b")), 1);
        assert_eq!(log.end_offset(), 2);
    }

    #[test]
    fn read_window() {
        let log = PartitionLog::new();
        for i in 0..10 {
            log.append(Message::from_str(&format!("m{i}")));
        }
        let batch = log.read(3, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].0, 3);
        assert_eq!(batch[0].1.payload_str(), Some("m3"));
        assert_eq!(batch[3].0, 6);
        // Past the end.
        assert!(log.read(10, 5).is_empty());
        assert!(log.read(99, 5).is_empty());
        // Partial tail.
        assert_eq!(log.read(8, 5).len(), 2);
    }

    #[test]
    fn append_batch_dense_in_order() {
        let log = PartitionLog::new();
        log.append(Message::from_str("pre"));
        let base = log.append_batch((0..5).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(base, 1);
        assert_eq!(log.end_offset(), 6);
        let got = log.read(1, 10);
        assert_eq!(got.len(), 5);
        for (i, (off, m)) in got.iter().enumerate() {
            assert_eq!(*off, 1 + i as u64);
            assert_eq!(m.payload[0], i as u8);
        }
        // Empty batch: no-op, returns the end offset.
        assert_eq!(log.append_batch(Vec::new()), 6);
        assert_eq!(log.end_offset(), 6);
    }

    #[test]
    fn appends_span_segment_boundaries() {
        let log = PartitionLog::new();
        let total = SEGMENT_SLOTS * 3 + 7;
        // Mixed batch sizes so boundaries land mid-batch and mid-message.
        let mut sent = 0usize;
        while sent < total {
            let n = (sent % 321 + 1).min(total - sent);
            let base = log.append_batch(
                (0..n).map(|i| Message::new(None, ((sent + i) as u32).to_le_bytes().to_vec(), 0)).collect(),
            );
            assert_eq!(base, sent as u64);
            sent += n;
        }
        assert_eq!(log.end_offset(), total as u64);
        // Reads that start/end inside every segment, including across the
        // boundary slots.
        for start in [0, SEGMENT_SLOTS - 1, SEGMENT_SLOTS, 2 * SEGMENT_SLOTS - 3, total - 5] {
            let got = log.read(start as u64, 10);
            assert_eq!(got.len(), 10.min(total - start));
            for (off, m) in got {
                let mut b = [0u8; 4];
                b.copy_from_slice(&m.payload);
                assert_eq!(u32::from_le_bytes(b) as u64, off, "slot holds its own offset");
            }
        }
    }

    #[test]
    fn concurrent_appends_keep_all() {
        let log = Arc::new(PartitionLog::new());
        let mut handles = vec![];
        for t in 0..4 {
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    log.append(Message::new(Some(t), vec![i as u8], 0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.end_offset(), 4000);
        // Offsets dense: read everything back.
        assert_eq!(log.read(0, 5000).len(), 4000);
    }

    #[test]
    fn readers_race_writers_without_torn_reads() {
        let log = Arc::new(PartitionLog::new());
        let total = SEGMENT_SLOTS as u64 * 2 + 100;
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for i in 0..total {
                    log.append(Message::new(None, (i as u32).to_le_bytes().to_vec(), 0));
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut next = 0u64;
                    while next < total {
                        let got = log.read(next, 64);
                        if got.is_empty() {
                            std::thread::yield_now();
                            continue;
                        }
                        for (off, m) in got {
                            assert_eq!(off, next, "dense, in-order delivery");
                            let mut b = [0u8; 4];
                            b.copy_from_slice(&m.payload);
                            assert_eq!(u32::from_le_bytes(b) as u64, off, "no torn slot");
                            next += 1;
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(log.end_offset(), total);
    }
}
