//! A partition: an append-only, offset-indexed message log.

use super::message::Message;
use std::sync::RwLock;

/// Append-only log. Offsets are dense and start at 0; reads never block
/// appends for long (the lock covers a Vec push / slice clone).
pub struct PartitionLog {
    entries: RwLock<Vec<Message>>,
}

impl PartitionLog {
    pub fn new() -> Self {
        PartitionLog { entries: RwLock::new(Vec::new()) }
    }

    /// Append one message, returning its offset.
    pub fn append(&self, msg: Message) -> u64 {
        let mut e = self.entries.write().unwrap();
        e.push(msg);
        (e.len() - 1) as u64
    }

    /// Append a whole batch under one lock acquisition, returning the
    /// offset of the first appended message (the batch occupies the dense
    /// range `base..base + msgs.len()`, in input order). This is the
    /// messaging layer's write-side fast path: the per-append lock cost is
    /// paid once per batch instead of once per message. For an empty batch
    /// the current end offset is returned and nothing is written.
    pub fn append_batch(&self, msgs: Vec<Message>) -> u64 {
        let mut e = self.entries.write().unwrap();
        let base = e.len() as u64;
        e.extend(msgs);
        base
    }

    /// First offset *past* the log end (== number of messages).
    pub fn end_offset(&self) -> u64 {
        self.entries.read().unwrap().len() as u64
    }

    /// Read up to `max` messages starting at `from` (clamped to log end).
    /// Returns `(offset, message)` pairs; message clones are refcount bumps.
    pub fn read(&self, from: u64, max: usize) -> Vec<(u64, Message)> {
        let e = self.entries.read().unwrap();
        let start = (from as usize).min(e.len());
        let end = start.saturating_add(max).min(e.len());
        (start..end).map(|i| (i as u64, e[i].clone())).collect()
    }
}

impl Default for PartitionLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn append_assigns_dense_offsets() {
        let log = PartitionLog::new();
        assert_eq!(log.append(Message::from_str("a")), 0);
        assert_eq!(log.append(Message::from_str("b")), 1);
        assert_eq!(log.end_offset(), 2);
    }

    #[test]
    fn read_window() {
        let log = PartitionLog::new();
        for i in 0..10 {
            log.append(Message::from_str(&format!("m{i}")));
        }
        let batch = log.read(3, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].0, 3);
        assert_eq!(batch[0].1.payload_str(), Some("m3"));
        assert_eq!(batch[3].0, 6);
        // Past the end.
        assert!(log.read(10, 5).is_empty());
        assert!(log.read(99, 5).is_empty());
        // Partial tail.
        assert_eq!(log.read(8, 5).len(), 2);
    }

    #[test]
    fn append_batch_dense_in_order() {
        let log = PartitionLog::new();
        log.append(Message::from_str("pre"));
        let base = log.append_batch((0..5).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(base, 1);
        assert_eq!(log.end_offset(), 6);
        let got = log.read(1, 10);
        assert_eq!(got.len(), 5);
        for (i, (off, m)) in got.iter().enumerate() {
            assert_eq!(*off, 1 + i as u64);
            assert_eq!(m.payload[0], i as u8);
        }
        // Empty batch: no-op, returns the end offset.
        assert_eq!(log.append_batch(Vec::new()), 6);
        assert_eq!(log.end_offset(), 6);
    }

    #[test]
    fn concurrent_appends_keep_all() {
        let log = Arc::new(PartitionLog::new());
        let mut handles = vec![];
        for t in 0..4 {
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    log.append(Message::new(Some(t), vec![i as u8], 0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.end_offset(), 4000);
        // Offsets dense: read everything back.
        assert_eq!(log.read(0, 5000).len(), 4000);
    }
}
