//! A partition: an append-only, offset-indexed message log.
//!
//! # Segmented, lock-free-read layout
//!
//! The log is a forward-linked chain of fixed-capacity **segments** of
//! [`SEGMENT_SLOTS`] message slots each. Offsets are dense and start at 0;
//! offset `o` lives in slot `o - base` of the segment whose `base` covers
//! it. Segments are only ever appended, never resized or removed, so a
//! message's slot address is stable for the life of the log — appends
//! never reallocate, and a reader is never invalidated by a concurrent
//! append (the `RwLock<Vec<_>>` this replaced memcpy'd the whole log on
//! every regrow, stalling all readers behind the write lock).
//!
//! # Tail-publish protocol
//!
//! - **Appends** serialize on a small writer mutex (writers only contend
//!   with other writers). The holder writes messages into unpublished
//!   slots, links a fresh segment when the current one fills, and then
//!   *publishes* the batch with one release-store of the `tail` counter.
//! - **Reads take no lock at all**: an acquire-load of `tail` makes every
//!   slot write and segment link below it visible, so readers walk the
//!   committed prefix directly. `read`/`end_offset` cost the same whether
//!   zero or a thousand other threads are polling.
//!
//! Slots at or above `tail` are only touched by the writer holding the
//! mutex; slots below `tail` are immutable. That single invariant is what
//! the `unsafe` blocks below rely on.
//!
//! # Durability hook
//!
//! A log may carry an attached [`PartitionStore`]. Appends then persist
//! the batch **first** — still under the writer mutex, still before the
//! tail publish — so disk order, memory order, and the offsets consumers
//! are acked against are always the same sequence. A log without a store
//! behaves exactly as before (the store check is one `OnceLock` load).

use super::message::Message;
use super::storage::PartitionStore;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Messages per segment. Large enough that chain hops are rare on batch
/// reads, small enough that a fresh partition costs ~one page of slots.
pub const SEGMENT_SLOTS: usize = 1024;

/// One fixed-capacity run of message slots.
///
/// `slots[i]` holds offset `base + i`. A slot is written exactly once (by
/// the appender that claimed it, under the writer mutex) and becomes
/// immutable once the log's `tail` counter passes it.
struct Segment {
    /// Offset of `slots[0]`.
    base: u64,
    slots: Box<[UnsafeCell<MaybeUninit<Message>>]>,
    /// The following segment (set once, by the writer that filled this
    /// one). Readers traverse it only for offsets below the published
    /// tail, which the tail's release/acquire edge makes safe.
    next: OnceLock<Arc<Segment>>,
    /// How many leading slots hold initialized messages — only consulted
    /// on drop (the happens-before edge is `Arc`'s refcount teardown).
    init: AtomicUsize,
}

// SAFETY: the `UnsafeCell` slots are written only by the single thread
// holding the log's writer mutex, and only while the slot is above the
// published tail; every other access (reads below the tail, drop) sees
// the slot after a release/acquire or refcount synchronization point.
unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

impl Segment {
    fn new(base: u64) -> Self {
        Segment {
            base,
            slots: (0..SEGMENT_SLOTS).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect(),
            next: OnceLock::new(),
            init: AtomicUsize::new(0),
        }
    }
}

impl Drop for Segment {
    fn drop(&mut self) {
        let n = *self.init.get_mut();
        for slot in self.slots.iter_mut().take(n) {
            // SAFETY: the writer initialized exactly the first `init`
            // slots; `&mut self` proves no reader can observe them now.
            unsafe { slot.get_mut().assume_init_drop() };
        }
    }
}

/// Append-only log with lock-free reads (see the module docs for the
/// segment layout and the tail-publish protocol).
pub struct PartitionLog {
    /// First segment (base 0). Owns the whole chain via `Segment::next`.
    head: Arc<Segment>,
    /// The segment currently being filled — a cursor into the chain so
    /// near-tail readers and the appender skip the head walk. Always
    /// points at a segment kept alive by the chain.
    tail_seg: AtomicPtr<Segment>,
    /// First offset past the published prefix. The release-store here is
    /// what hands finished slots over to readers.
    tail: AtomicU64,
    /// Serializes appenders (and only appenders) — never held by readers.
    writer: Mutex<()>,
    /// Durable backing, if any. Set once during recovery wiring; appends
    /// write through it before publishing to readers.
    store: OnceLock<Arc<dyn PartitionStore>>,
}

impl PartitionLog {
    pub fn new() -> Self {
        let head = Arc::new(Segment::new(0));
        let tail_seg = AtomicPtr::new(Arc::as_ptr(&head) as *mut Segment);
        PartitionLog {
            head,
            tail_seg,
            tail: AtomicU64::new(0),
            writer: Mutex::new(()),
            store: OnceLock::new(),
        }
    }

    /// Attach a durable store. Called once during recovery wiring, after
    /// [`PartitionLog::restore`] replayed the store's messages, so the
    /// two ends must already agree — from here on every append writes
    /// through the store before it is published.
    pub fn attach_store(&self, store: Arc<dyn PartitionStore>) {
        let _guard = self.writer.lock().unwrap();
        assert_eq!(
            store.end_offset(),
            self.tail.load(Ordering::Relaxed),
            "store and log must agree on the end offset before attachment"
        );
        assert!(self.store.set(store).is_ok(), "store attached twice");
    }

    /// Replay recovered messages into a log that has no store attached
    /// yet (recovery only — the store already holds these records).
    pub fn restore(&self, msgs: Vec<Message>) {
        assert!(self.store.get().is_none(), "restore must precede attach_store");
        if msgs.is_empty() {
            return;
        }
        let _guard = self.writer.lock().unwrap();
        let base = self.tail.load(Ordering::Relaxed);
        let n = msgs.len() as u64;
        self.write_slots_locked(base, msgs.into_iter());
        self.tail.store(base + n, Ordering::Release);
    }

    /// Append one message, returning its offset.
    pub fn append(&self, msg: Message) -> u64 {
        let _guard = self.writer.lock().unwrap();
        // Only the mutex holder stores `tail`, so this read is exact.
        let base = self.tail.load(Ordering::Relaxed);
        if let Some(store) = self.store.get() {
            // Persist before publish: a message a reader can see is
            // already on disk (see the module docs).
            store.append_batch(std::slice::from_ref(&msg));
        }
        self.write_slots_locked(base, std::iter::once(msg));
        self.tail.store(base + 1, Ordering::Release);
        base
    }

    /// Append a whole batch under one writer-mutex acquisition, returning
    /// the offset of the first appended message (the batch occupies the
    /// dense range `base..base + msgs.len()`, in input order). The batch
    /// becomes visible to readers atomically: one tail publish covers all
    /// of it. For an empty batch the current end offset is returned and
    /// nothing is written.
    pub fn append_batch(&self, msgs: Vec<Message>) -> u64 {
        let _guard = self.writer.lock().unwrap();
        let base = self.tail.load(Ordering::Relaxed);
        if msgs.is_empty() {
            return base;
        }
        if let Some(store) = self.store.get() {
            store.append_batch(&msgs);
        }
        let n = msgs.len() as u64;
        self.write_slots_locked(base, msgs.into_iter());
        self.tail.store(base + n, Ordering::Release);
        base
    }

    /// Conditional append for replica applies: append only the part of
    /// `msgs` the log does not already hold, keyed on the batch's claimed
    /// `base` offset. Returns `(end, appended)` — the log end after the
    /// call and how many messages were actually written:
    ///
    /// - `base == end` — contiguous: append everything;
    /// - `base + msgs.len() <= end` — pure duplicate: no-op;
    /// - `base < end < base + msgs.len()` — overlap: append the unseen
    ///   suffix;
    /// - `base > end` — a gap: refuse the batch (append nothing).
    ///
    /// The check and the append happen under one writer-mutex
    /// acquisition, so two concurrent replica streams (a live forward
    /// and a catch-up pull, say) can never both pass the duplicate check
    /// and fork the log — each call sees the end the previous appender
    /// published.
    pub fn append_batch_from(&self, base: u64, msgs: Vec<Message>) -> (u64, u64) {
        let _guard = self.writer.lock().unwrap();
        let end = self.tail.load(Ordering::Relaxed);
        let n = msgs.len() as u64;
        if n == 0 || base > end || base + n <= end {
            return (end, 0);
        }
        let fresh: Vec<Message> = msgs.into_iter().skip((end - base) as usize).collect();
        if let Some(store) = self.store.get() {
            store.append_batch(&fresh);
        }
        let appended = fresh.len() as u64;
        self.write_slots_locked(end, fresh.into_iter());
        self.tail.store(end + appended, Ordering::Release);
        (end + appended, appended)
    }

    /// Write `msgs` into the slots starting at `base`. Caller holds the
    /// writer mutex and publishes the tail afterwards.
    fn write_slots_locked<I>(&self, base: u64, msgs: I)
    where
        I: Iterator<Item = Message>,
    {
        // SAFETY: `tail_seg` points into the chain owned by `self.head`,
        // and segments are never unlinked while `&self` is alive.
        let mut seg: &Segment = unsafe { &*self.tail_seg.load(Ordering::Relaxed) };
        for (i, msg) in msgs.enumerate() {
            let off = base + i as u64;
            let mut idx = (off - seg.base) as usize;
            if idx == SEGMENT_SLOTS {
                // Current segment is full: link its successor and move the
                // tail-segment cursor forward. Readers may only follow the
                // link for offsets below the published tail, all of which
                // stay in earlier segments until the store below.
                let next = Arc::new(Segment::new(off));
                let ptr = Arc::as_ptr(&next) as *mut Segment;
                assert!(seg.next.set(next).is_ok(), "tail segment linked twice");
                self.tail_seg.store(ptr, Ordering::Release);
                // SAFETY: the chain now owns the segment behind `ptr`.
                seg = unsafe { &*ptr };
                idx = 0;
            }
            // SAFETY: `off >= tail`, so no reader touches this slot yet,
            // and the writer mutex excludes every other appender.
            unsafe { seg.slots[idx].get().write(MaybeUninit::new(msg)) };
            seg.init.store(idx + 1, Ordering::Relaxed);
        }
        // The caller's release-store of `tail` publishes these writes:
        // everything above happens-before any reader's acquire-load that
        // observes the new tail.
    }

    /// First offset *past* the log end (== number of messages).
    pub fn end_offset(&self) -> u64 {
        self.tail.load(Ordering::Acquire)
    }

    /// Read up to `max` messages starting at `from` (clamped to log end).
    /// Returns `(offset, message)` pairs; message clones are refcount
    /// bumps. Takes no lock: one acquire-load of the tail, then direct
    /// slot reads of the committed prefix.
    pub fn read(&self, from: u64, max: usize) -> Vec<(u64, Message)> {
        let end = self.tail.load(Ordering::Acquire);
        if from >= end || max == 0 {
            return Vec::new();
        }
        let stop = from.saturating_add(max as u64).min(end);
        let mut out = Vec::with_capacity((stop - from) as usize);
        let mut seg = self.seek(from);
        for off in from..stop {
            let mut idx = (off - seg.base) as usize;
            if idx == SEGMENT_SLOTS {
                seg = seg.next.get().expect("offsets below the tail are linked").as_ref();
                idx = 0;
            }
            // SAFETY: `off < end`, and the acquire-load of `tail` above
            // synchronized with the release-store that published `off`'s
            // slot write; published slots are immutable.
            let msg = unsafe { (*seg.slots[idx].get()).assume_init_ref().clone() };
            out.push((off, msg));
        }
        out
    }

    /// Read up to `max` messages starting at `from` as a [`BatchRef`] —
    /// shared slices straight into the segment chain, no per-message
    /// clone. The returned batch pins its segments alive (each slice
    /// holds an `Arc<Segment>`), so it stays valid across concurrent
    /// appends, segment rolls, and even the log being dropped.
    pub fn read_ref(&self, from: u64, max: usize) -> BatchRef {
        let end = self.tail.load(Ordering::Acquire);
        if from >= end || max == 0 {
            return BatchRef::empty();
        }
        let stop = from.saturating_add(max as u64).min(end);
        let mut slices = Vec::new();
        let mut seg = self.seek_arc(from);
        let mut off = from;
        while off < stop {
            if (off - seg.base) as usize == SEGMENT_SLOTS {
                let next =
                    seg.next.get().expect("offsets below the tail are linked").clone();
                seg = next;
            }
            let start = (off - seg.base) as usize;
            let run = ((stop - off) as usize).min(SEGMENT_SLOTS - start);
            slices.push(MessageSlice { first_offset: off, start, len: run, seg: seg.clone() });
            off += run as u64;
        }
        BatchRef { len: (stop - from) as usize, slices }
    }

    /// Like [`seek`](Self::seek) but returns an owning handle, for reads
    /// that outlive the borrow of `self`.
    fn seek_arc(&self, offset: u64) -> Arc<Segment> {
        let ptr = self.tail_seg.load(Ordering::Acquire);
        // SAFETY: the cursor always points at a segment owned by the
        // chain rooted at `self.head`, which stays alive while `&self`
        // does; reviving an extra strong count from a live Arc is sound.
        let tail_seg = unsafe {
            Arc::increment_strong_count(ptr);
            Arc::from_raw(ptr as *const Segment)
        };
        if offset >= tail_seg.base {
            return tail_seg;
        }
        let mut seg = self.head.clone();
        while offset >= seg.base + SEGMENT_SLOTS as u64 {
            let next = seg.next.get().expect("offsets below the tail are linked").clone();
            seg = next;
        }
        seg
    }

    /// Segment containing `offset`. Callers must have observed a
    /// published tail greater than `offset`.
    fn seek(&self, offset: u64) -> &Segment {
        // Fast path: consumers overwhelmingly read near the tail.
        // SAFETY: the cursor always points at a chain-owned segment; the
        // acquire-load pairs with the release-store in `append_iter` so
        // the segment's fields are visible.
        let tail_seg: &Segment = unsafe { &*self.tail_seg.load(Ordering::Acquire) };
        if offset >= tail_seg.base {
            return tail_seg;
        }
        let mut seg: &Segment = &self.head;
        while offset >= seg.base + SEGMENT_SLOTS as u64 {
            seg = seg.next.get().expect("offsets below the tail are linked").as_ref();
        }
        seg
    }
}

impl Drop for PartitionLog {
    fn drop(&mut self) {
        // Unlink the chain iteratively so a long log can't overflow the
        // stack with recursive `Arc<Segment>` drops. A segment pinned by
        // a live [`BatchRef`] stops the walk early (`get_mut` fails);
        // it, and everything it links to, lives until that batch drops.
        let mut cur = Arc::get_mut(&mut self.head).and_then(|s| s.next.take());
        while let Some(mut seg) = cur {
            cur = Arc::get_mut(&mut seg).and_then(|s| s.next.take());
        }
    }
}

/// A run of consecutive published messages inside one segment, pinned by
/// an owning handle. Offsets are `first_offset..first_offset + len`.
pub struct MessageSlice {
    seg: Arc<Segment>,
    /// Slot index of the first message within `seg`.
    start: usize,
    len: usize,
    first_offset: u64,
}

impl MessageSlice {
    /// Borrow message `i` of this slice (`i < len`).
    fn get(&self, i: usize) -> &Message {
        debug_assert!(i < self.len);
        // SAFETY: `read_ref` only covered offsets below the published
        // tail it acquire-loaded, so these slots are initialized and
        // immutable; the `Arc` keeps the segment alive for `&self`.
        unsafe { (*self.seg.slots[self.start + i].get()).assume_init_ref() }
    }
}

/// A shared-slice range read: the zero-copy counterpart of
/// [`PartitionLog::read`]. Holds `Arc`'d segment handles instead of
/// cloned messages, so delivering a batch to the wire costs refcount
/// bumps, not per-message copies — and the batch stays readable across
/// segment rolls, concurrent appends, and the log's own drop.
pub struct BatchRef {
    slices: Vec<MessageSlice>,
    len: usize,
}

impl BatchRef {
    pub fn empty() -> Self {
        BatchRef { slices: Vec::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Offset of the first message (`None` when empty).
    pub fn first_offset(&self) -> Option<u64> {
        self.slices.first().map(|s| s.first_offset)
    }

    /// Offset of the last message (`None` when empty).
    pub fn last_offset(&self) -> Option<u64> {
        self.slices.last().map(|s| s.first_offset + s.len as u64 - 1)
    }

    /// Borrow message `i` with its offset.
    pub fn get(&self, mut i: usize) -> Option<(u64, &Message)> {
        if i >= self.len {
            return None;
        }
        for s in &self.slices {
            if i < s.len {
                return Some((s.first_offset + i as u64, s.get(i)));
            }
            i -= s.len;
        }
        None
    }

    /// Iterate `(offset, &message)` in offset order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &Message)> {
        self.slices
            .iter()
            .flat_map(|s| (0..s.len).map(move |i| (s.first_offset + i as u64, s.get(i))))
    }

    /// Keep only the first `n` messages (byte-budget truncation).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len {
            return;
        }
        let mut kept = 0;
        self.slices.retain_mut(|s| {
            if kept >= n {
                return false;
            }
            if kept + s.len > n {
                s.len = n - kept;
            }
            kept += s.len;
            true
        });
        self.len = n;
    }

    /// Materialize into owned `(offset, message)` pairs (compat path;
    /// clones are refcount bumps on the payload).
    pub fn to_vec(&self) -> Vec<(u64, Message)> {
        self.iter().map(|(off, m)| (off, m.clone())).collect()
    }
}

impl Default for PartitionLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Records every appended message; end offset tracks the log's.
    struct RecordingStore {
        seen: Mutex<Vec<Message>>,
    }

    impl PartitionStore for RecordingStore {
        fn append_batch(&self, msgs: &[Message]) {
            self.seen.lock().unwrap().extend_from_slice(msgs);
        }
        fn end_offset(&self) -> u64 {
            self.seen.lock().unwrap().len() as u64
        }
        fn sync(&self) {}
    }

    #[test]
    fn attached_store_sees_every_append_in_offset_order() {
        let log = PartitionLog::new();
        let store = Arc::new(RecordingStore { seen: Mutex::new(Vec::new()) });
        log.attach_store(store.clone());
        log.append(Message::from_str("a"));
        log.append_batch(vec![Message::from_str("b"), Message::from_str("c")]);
        log.append_batch(Vec::new()); // empty batch never reaches the store
        let seen = store.seen.lock().unwrap();
        let texts: Vec<_> = seen.iter().map(|m| m.payload_str().unwrap()).collect();
        assert_eq!(texts, ["a", "b", "c"], "store order == offset order");
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn restore_then_attach_resumes_offsets() {
        let log = PartitionLog::new();
        let recovered = vec![Message::from_str("r0"), Message::from_str("r1")];
        log.restore(recovered.clone());
        assert_eq!(log.end_offset(), 2);
        assert_eq!(log.read(0, 10).len(), 2);
        let store = Arc::new(RecordingStore { seen: Mutex::new(recovered) });
        log.attach_store(store.clone());
        assert_eq!(log.append(Message::from_str("new")), 2, "appends continue past recovery");
        assert_eq!(store.seen.lock().unwrap().len(), 3);
    }

    #[test]
    #[should_panic(expected = "agree on the end offset")]
    fn attach_store_rejects_offset_mismatch() {
        let log = PartitionLog::new();
        log.restore(vec![Message::from_str("x")]);
        log.attach_store(Arc::new(RecordingStore { seen: Mutex::new(Vec::new()) }));
    }

    #[test]
    fn append_assigns_dense_offsets() {
        let log = PartitionLog::new();
        assert_eq!(log.append(Message::from_str("a")), 0);
        assert_eq!(log.append(Message::from_str("b")), 1);
        assert_eq!(log.end_offset(), 2);
    }

    #[test]
    fn read_window() {
        let log = PartitionLog::new();
        for i in 0..10 {
            log.append(Message::from_str(&format!("m{i}")));
        }
        let batch = log.read(3, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].0, 3);
        assert_eq!(batch[0].1.payload_str(), Some("m3"));
        assert_eq!(batch[3].0, 6);
        // Past the end.
        assert!(log.read(10, 5).is_empty());
        assert!(log.read(99, 5).is_empty());
        // Partial tail.
        assert_eq!(log.read(8, 5).len(), 2);
    }

    #[test]
    fn append_batch_dense_in_order() {
        let log = PartitionLog::new();
        log.append(Message::from_str("pre"));
        let base = log.append_batch((0..5).map(|i| Message::new(None, vec![i], 0)).collect());
        assert_eq!(base, 1);
        assert_eq!(log.end_offset(), 6);
        let got = log.read(1, 10);
        assert_eq!(got.len(), 5);
        for (i, (off, m)) in got.iter().enumerate() {
            assert_eq!(*off, 1 + i as u64);
            assert_eq!(m.payload[0], i as u8);
        }
        // Empty batch: no-op, returns the end offset.
        assert_eq!(log.append_batch(Vec::new()), 6);
        assert_eq!(log.end_offset(), 6);
    }

    #[test]
    fn append_batch_from_is_idempotent_and_gap_safe() {
        let log = PartitionLog::new();
        let batch = |base: u64, n: u64| -> Vec<Message> {
            (base..base + n).map(|o| Message::new(None, vec![o as u8], 0)).collect()
        };
        // Contiguous, then an exact duplicate (a retry): no-op.
        assert_eq!(log.append_batch_from(0, batch(0, 3)), (3, 3));
        assert_eq!(log.append_batch_from(0, batch(0, 3)), (3, 0));
        // Overlap appends only the unseen suffix.
        assert_eq!(log.append_batch_from(1, batch(1, 4)), (5, 2));
        // A gap is refused outright.
        assert_eq!(log.append_batch_from(10, batch(10, 2)), (5, 0));
        // Empty batches never move the end.
        assert_eq!(log.append_batch_from(5, Vec::new()), (5, 0));
        let got = log.read(0, 10);
        assert_eq!(got.len(), 5);
        for (off, m) in got {
            assert_eq!(m.payload, vec![off as u8], "offset {off} holds its own value");
        }
    }

    #[test]
    fn append_batch_from_writes_suffix_through_the_store() {
        let log = PartitionLog::new();
        let store = Arc::new(RecordingStore { seen: Mutex::new(Vec::new()) });
        log.attach_store(store.clone());
        let batch = |base: u64, n: u64| -> Vec<Message> {
            (base..base + n).map(|o| Message::new(None, vec![o as u8], 0)).collect()
        };
        log.append_batch_from(0, batch(0, 3));
        log.append_batch_from(0, batch(0, 3)); // duplicate: nothing persisted
        log.append_batch_from(1, batch(1, 4)); // overlap: only offsets 3, 4
        let seen = store.seen.lock().unwrap();
        let vals: Vec<u8> = seen.iter().map(|m| m.payload[0]).collect();
        assert_eq!(vals, [0, 1, 2, 3, 4], "store holds each offset exactly once");
    }

    #[test]
    fn concurrent_conditional_appends_never_fork_the_log() {
        // Two "replica streams" race the same batches at the same claimed
        // base offsets — the interleaving the conditional append exists
        // to survive. Whatever the schedule, the log must end dense with
        // each offset written exactly once.
        let log = Arc::new(PartitionLog::new());
        let rounds = 200u64;
        let span = 4u64;
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for round in 0..rounds {
                        let base = round * span;
                        loop {
                            let msgs: Vec<Message> = (base..base + span)
                                .map(|o| Message::new(None, (o as u32).to_le_bytes().to_vec(), 0))
                                .collect();
                            let (end, _) = log.append_batch_from(base, msgs);
                            if end >= base + span {
                                break; // this round landed (here or on the other thread)
                            }
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.end_offset(), rounds * span);
        for (off, m) in log.read(0, (rounds * span) as usize) {
            let mut b = [0u8; 4];
            b.copy_from_slice(&m.payload);
            assert_eq!(u32::from_le_bytes(b) as u64, off, "offset {off} duplicated or torn");
        }
    }

    #[test]
    fn appends_span_segment_boundaries() {
        let log = PartitionLog::new();
        let total = SEGMENT_SLOTS * 3 + 7;
        // Mixed batch sizes so boundaries land mid-batch and mid-message.
        let mut sent = 0usize;
        while sent < total {
            let n = (sent % 321 + 1).min(total - sent);
            let base = log.append_batch(
                (0..n).map(|i| Message::new(None, ((sent + i) as u32).to_le_bytes().to_vec(), 0)).collect(),
            );
            assert_eq!(base, sent as u64);
            sent += n;
        }
        assert_eq!(log.end_offset(), total as u64);
        // Reads that start/end inside every segment, including across the
        // boundary slots.
        for start in [0, SEGMENT_SLOTS - 1, SEGMENT_SLOTS, 2 * SEGMENT_SLOTS - 3, total - 5] {
            let got = log.read(start as u64, 10);
            assert_eq!(got.len(), 10.min(total - start));
            for (off, m) in got {
                let mut b = [0u8; 4];
                b.copy_from_slice(&m.payload);
                assert_eq!(u32::from_le_bytes(b) as u64, off, "slot holds its own offset");
            }
        }
    }

    #[test]
    fn concurrent_appends_keep_all() {
        let log = Arc::new(PartitionLog::new());
        let mut handles = vec![];
        for t in 0..4 {
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    log.append(Message::new(Some(t), vec![i as u8], 0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.end_offset(), 4000);
        // Offsets dense: read everything back.
        assert_eq!(log.read(0, 5000).len(), 4000);
    }

    #[test]
    fn read_ref_matches_read_for_any_window() {
        let log = PartitionLog::new();
        let total = SEGMENT_SLOTS * 2 + 50;
        for i in 0..total {
            log.append(Message::new(Some(i as u64), (i as u32).to_le_bytes().to_vec(), i as u64));
        }
        for (from, max) in [
            (0usize, 10usize),
            (SEGMENT_SLOTS - 3, 7),
            (SEGMENT_SLOTS - 1, SEGMENT_SLOTS + 5),
            (0, total + 99),
            (total - 1, 4),
            (total, 4),
        ] {
            let owned = log.read(from as u64, max);
            let shared = log.read_ref(from as u64, max);
            assert_eq!(shared.len(), owned.len(), "window ({from}, {max})");
            for ((off_a, m_a), (off_b, m_b)) in owned.iter().zip(shared.iter()) {
                assert_eq!(*off_a, off_b);
                assert_eq!(m_a, m_b);
            }
            assert_eq!(shared.first_offset(), owned.first().map(|(o, _)| *o));
            assert_eq!(shared.last_offset(), owned.last().map(|(o, _)| *o));
        }
    }

    #[test]
    fn batch_ref_truncate_keeps_prefix() {
        let log = PartitionLog::new();
        let total = SEGMENT_SLOTS + 10;
        for i in 0..total {
            log.append(Message::new(None, (i as u32).to_le_bytes().to_vec(), 0));
        }
        // Spans the segment boundary; truncate to a prefix that also
        // spans it, then to one that doesn't.
        for keep in [SEGMENT_SLOTS + 4, 5, 0] {
            let mut b = log.read_ref(SEGMENT_SLOTS as u64 - 8, total);
            let before = b.to_vec();
            b.truncate(keep);
            assert_eq!(b.len(), keep.min(before.len()));
            for (i, (off, m)) in b.iter().enumerate() {
                assert_eq!((off, m), (before[i].0, &before[i].1));
            }
        }
    }

    #[test]
    fn batch_ref_survives_segment_roll_and_writer_progress() {
        let log = PartitionLog::new();
        for i in 0..100u32 {
            log.append(Message::new(None, i.to_le_bytes().to_vec(), 0));
        }
        let held = log.read_ref(40, 20);
        let snapshot = held.to_vec();
        // Writer rolls several segments forward while the batch is held.
        for i in 100..(SEGMENT_SLOTS as u32 * 3) {
            log.append(Message::new(None, i.to_le_bytes().to_vec(), 0));
        }
        assert_eq!(held.len(), 20);
        for (i, (off, m)) in held.iter().enumerate() {
            assert_eq!(off, 40 + i as u64);
            assert_eq!((off, m), (snapshot[i].0, &snapshot[i].1));
        }
    }

    #[test]
    fn batch_ref_outlives_dropped_log() {
        let log = PartitionLog::new();
        let total = SEGMENT_SLOTS + 20; // batch spans the first boundary
        for i in 0..total {
            log.append(Message::new(Some(i as u64), (i as u32).to_le_bytes().to_vec(), 7));
        }
        let held = log.read_ref(SEGMENT_SLOTS as u64 - 10, 30);
        assert_eq!(held.len(), 30);
        drop(log);
        for (i, (off, m)) in held.iter().enumerate() {
            let expect = SEGMENT_SLOTS as u64 - 10 + i as u64;
            assert_eq!(off, expect);
            assert_eq!(m.key, Some(expect));
            let mut b = [0u8; 4];
            b.copy_from_slice(&m.payload);
            assert_eq!(u32::from_le_bytes(b) as u64, expect);
        }
    }

    #[test]
    fn shared_readers_race_writers_without_torn_reads() {
        let log = Arc::new(PartitionLog::new());
        let total = SEGMENT_SLOTS as u64 * 2 + 100;
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for i in 0..total {
                    log.append(Message::new(None, (i as u32).to_le_bytes().to_vec(), 0));
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut next = 0u64;
                    let mut held: Vec<BatchRef> = Vec::new();
                    while next < total {
                        let got = log.read_ref(next, 64);
                        if got.is_empty() {
                            std::thread::yield_now();
                            continue;
                        }
                        for (off, m) in got.iter() {
                            assert_eq!(off, next, "dense, in-order delivery");
                            let mut b = [0u8; 4];
                            b.copy_from_slice(&m.payload);
                            assert_eq!(u32::from_le_bytes(b) as u64, off, "no torn slot");
                            next += 1;
                        }
                        // Hold every 8th batch across the writer's
                        // further progress, re-checking it at the end.
                        if next % 512 < 64 {
                            held.push(got);
                        }
                    }
                    for b in &held {
                        for (off, m) in b.iter() {
                            let mut raw = [0u8; 4];
                            raw.copy_from_slice(&m.payload);
                            assert_eq!(u32::from_le_bytes(raw) as u64, off, "held batch stable");
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(log.end_offset(), total);
    }

    #[test]
    fn readers_race_writers_without_torn_reads() {
        let log = Arc::new(PartitionLog::new());
        let total = SEGMENT_SLOTS as u64 * 2 + 100;
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for i in 0..total {
                    log.append(Message::new(None, (i as u32).to_le_bytes().to_vec(), 0));
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut next = 0u64;
                    while next < total {
                        let got = log.read(next, 64);
                        if got.is_empty() {
                            std::thread::yield_now();
                            continue;
                        }
                        for (off, m) in got {
                            assert_eq!(off, next, "dense, in-order delivery");
                            let mut b = [0u8; 4];
                            b.copy_from_slice(&m.payload);
                            assert_eq!(u32::from_le_bytes(b) as u64, off, "no torn slot");
                            next += 1;
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(log.end_offset(), total);
    }
}
