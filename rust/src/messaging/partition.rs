//! A partition: an append-only, offset-indexed message log.

use super::message::Message;
use std::sync::RwLock;

/// Append-only log. Offsets are dense and start at 0; reads never block
/// appends for long (the lock covers a Vec push / slice clone).
pub struct PartitionLog {
    entries: RwLock<Vec<Message>>,
}

impl PartitionLog {
    pub fn new() -> Self {
        PartitionLog { entries: RwLock::new(Vec::new()) }
    }

    /// Append one message, returning its offset.
    pub fn append(&self, msg: Message) -> u64 {
        let mut e = self.entries.write().unwrap();
        e.push(msg);
        (e.len() - 1) as u64
    }

    /// First offset *past* the log end (== number of messages).
    pub fn end_offset(&self) -> u64 {
        self.entries.read().unwrap().len() as u64
    }

    /// Read up to `max` messages starting at `from` (clamped to log end).
    /// Returns `(offset, message)` pairs; message clones are refcount bumps.
    pub fn read(&self, from: u64, max: usize) -> Vec<(u64, Message)> {
        let e = self.entries.read().unwrap();
        let start = (from as usize).min(e.len());
        let end = start.saturating_add(max).min(e.len());
        (start..end).map(|i| (i as u64, e[i].clone())).collect()
    }
}

impl Default for PartitionLog {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn append_assigns_dense_offsets() {
        let log = PartitionLog::new();
        assert_eq!(log.append(Message::from_str("a")), 0);
        assert_eq!(log.append(Message::from_str("b")), 1);
        assert_eq!(log.end_offset(), 2);
    }

    #[test]
    fn read_window() {
        let log = PartitionLog::new();
        for i in 0..10 {
            log.append(Message::from_str(&format!("m{i}")));
        }
        let batch = log.read(3, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].0, 3);
        assert_eq!(batch[0].1.payload_str(), Some("m3"));
        assert_eq!(batch[3].0, 6);
        // Past the end.
        assert!(log.read(10, 5).is_empty());
        assert!(log.read(99, 5).is_empty());
        // Partial tail.
        assert_eq!(log.read(8, 5).len(), 2);
    }

    #[test]
    fn concurrent_appends_keep_all() {
        let log = Arc::new(PartitionLog::new());
        let mut handles = vec![];
        for t in 0..4 {
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    log.append(Message::new(Some(t), vec![i as u8], 0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.end_offset(), 4000);
        // Offsets dense: read everything back.
        assert_eq!(log.read(0, 5000).len(), 4000);
    }
}
