//! Component placement across nodes.

use super::node::{Cluster, ComponentHandle};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Round-robin placer (the paper's prototype spreads jobs' tasks over the
/// 3 nodes; nothing fancier is needed for the evaluation's shape).
pub struct Placement {
    cluster: Arc<Cluster>,
    next: AtomicUsize,
}

impl Placement {
    pub fn new(cluster: Arc<Cluster>) -> Self {
        Placement { cluster, next: AtomicUsize::new(0) }
    }

    /// Place a component on the next node in rotation; returns the node id.
    pub fn place(&self, handle: ComponentHandle) -> usize {
        let id = self.next.fetch_add(1, Ordering::Relaxed) % self.cluster.len();
        self.cluster.node(id).host(handle);
        id
    }

    /// Place on a *healthy* node if any (what Reactive Liquid's
    /// supervision does when regenerating); falls back to rotation.
    pub fn place_healthy(&self, handle: ComponentHandle) -> usize {
        let n = self.cluster.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for k in 0..n {
            let id = (start + k) % n;
            if self.cluster.node(id).is_up() {
                self.cluster.node(id).host(handle);
                return id;
            }
        }
        let id = start % n;
        self.cluster.node(id).host(handle);
        id
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noop(name: &str) -> ComponentHandle {
        ComponentHandle { name: name.into(), kill: Box::new(|| {}), respawn: Box::new(|| {}) }
    }

    #[test]
    fn round_robin_balances() {
        let c = Cluster::new(3);
        let p = Placement::new(c.clone());
        for i in 0..9 {
            p.place(noop(&format!("c{i}")));
        }
        for n in c.nodes() {
            assert_eq!(n.component_count(), 3);
        }
    }

    #[test]
    fn healthy_placement_skips_down_nodes() {
        let c = Cluster::new(3);
        let p = Placement::new(c.clone());
        c.node(0).fail();
        c.node(1).fail();
        for i in 0..4 {
            let id = p.place_healthy(noop(&format!("c{i}")));
            assert_eq!(id, 2, "only node 2 is up");
        }
        assert_eq!(c.node(2).component_count(), 4);
    }
}
