//! Partition and component placement across nodes.
//!
//! Two placers live here:
//!
//! - [`PlacementMap`] — the **multi-broker data plane's** deterministic
//!   map of `(topic, partition) → node`, built on rendezvous (highest
//!   random weight, HRW) hashing. Every node and every client computes
//!   owners *locally* from the same `(epoch, node set)` — no coordinator
//!   hands out assignments, and two processes holding the same map agree
//!   byte-for-byte (the HRW score is a pure integer mix, never a
//!   `HashMap` iteration order). On membership change, HRW moves only
//!   the partitions whose top-scoring node vanished or appeared —
//!   ~`1/N` of them — instead of reshuffling everything the way a
//!   modulo map would.
//! - [`Placement`] — the original round-robin *component* placer the
//!   in-process failure-injection sim uses (the paper's prototype
//!   spreads jobs' tasks over 3 nodes; nothing fancier is needed for
//!   that evaluation's shape).
//!
//! The map carries a **cluster epoch**: every failure-driven rebalance
//! bumps it, and both brokers and clients fence on it (see
//! [`ClusterView`](super::membership::ClusterView) and the owner checks
//! in [`BrokerService`](crate::transport::server::BrokerService)).

use super::node::{Cluster, ComponentHandle};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Rendezvous score of `node` for `(topic, partition)`: FNV-1a over the
/// three coordinates, finished with the SplitMix64 mixer. Pure and
/// process-independent — the property suite pins a golden value so an
/// accidental dependency on ambient state (hasher seeds, iteration
/// order) fails loudly.
pub fn hrw_score(node: &str, topic: &str, partition: usize) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        // Separator so ("ab","c") never collides with ("a","bc").
        h ^= 0xFF;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    };
    eat(node.as_bytes());
    eat(topic.as_bytes());
    eat(&(partition as u64).to_le_bytes());
    // SplitMix64 finalizer: FNV alone is weak in the high bits, and HRW
    // compares full words.
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// Default replication factor for clustered partitions: the HRW top-2
/// (primary + one follower) — enough to survive any single broker death
/// without tripling write amplification.
pub const DEFAULT_REPLICATION: usize = 2;

/// The deterministic `(topic, partition) → node` map: an epoch plus the
/// sorted `(node id, address)` set it was computed over. Owners are
/// *derived* (HRW), never stored — so shipping a map over the wire is
/// shipping `(epoch, nodes)` and nothing else. The same derivation
/// yields the ordered **replica set** ([`PlacementMap::replicas_of`]):
/// the HRW top-`k`, rank 0 being the primary (= [`PlacementMap::owner_of`]),
/// ranks 1.. the followers — so removing a dead primary from the node
/// set *is* the failover election: the old rank-1 follower becomes the
/// new rank 0 in the successor map, with no stored state to repair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlacementMap {
    epoch: u64,
    /// Sorted by node id, deduplicated.
    nodes: Vec<(String, String)>,
}

impl PlacementMap {
    /// Build a map at `epoch` over `nodes` (`(id, address)` pairs; order
    /// irrelevant, duplicates by id collapse to the first).
    pub fn new(epoch: u64, mut nodes: Vec<(String, String)>) -> Self {
        nodes.sort();
        nodes.dedup_by(|a, b| a.0 == b.0);
        PlacementMap { epoch, nodes }
    }

    /// The empty pre-cluster map (epoch 0, no owners).
    pub fn empty() -> Self {
        PlacementMap { epoch: 0, nodes: Vec::new() }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The `(id, address)` set, sorted by id.
    pub fn nodes(&self) -> &[(String, String)] {
        &self.nodes
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn contains(&self, node: &str) -> bool {
        self.nodes.iter().any(|(id, _)| id == node)
    }

    pub fn addr_of(&self, node: &str) -> Option<&str> {
        self.nodes.iter().find(|(id, _)| id == node).map(|(_, a)| a.as_str())
    }

    /// HRW owner of `(topic, partition)`: the node with the highest
    /// rendezvous score. Ties break toward the lexicographically smaller
    /// id (the node list is sorted and `max_by` keeps the *last* maximum,
    /// so we compare `(score, Reverse(id))` the simple way: strict
    /// greater-than keeps the first — smallest id — on equal scores).
    pub fn owner_of(&self, topic: &str, partition: usize) -> Option<&(String, String)> {
        let mut best: Option<(&(String, String), u64)> = None;
        for n in &self.nodes {
            let score = hrw_score(&n.0, topic, partition);
            match best {
                Some((_, s)) if s >= score => {}
                _ => best = Some((n, score)),
            }
        }
        best.map(|(n, _)| n)
    }

    /// Ordered replica set for `(topic, partition)`: the `k` nodes with
    /// the highest rendezvous scores, rank 0 first. Rank 0 is always the
    /// [`PlacementMap::owner_of`] primary (same scores, same tie-break:
    /// the node list is sorted by id and the sort is stable, so an equal
    /// score keeps the lexicographically smaller id in front). With
    /// fewer than `k` nodes every node is a replica.
    pub fn replicas_of(&self, topic: &str, partition: usize, k: usize) -> Vec<&(String, String)> {
        let mut scored: Vec<(&(String, String), u64)> =
            self.nodes.iter().map(|n| (n, hrw_score(&n.0, topic, partition))).collect();
        scored.sort_by(|a, b| b.1.cmp(&a.1));
        scored.truncate(k);
        scored.into_iter().map(|(n, _)| n).collect()
    }

    /// Rank of `node` in the replica set of `(topic, partition)` under
    /// replication factor `k`: `Some(0)` = primary, `Some(1..)` =
    /// follower, `None` = not a replica.
    pub fn replica_rank(&self, topic: &str, partition: usize, k: usize, node: &str) -> Option<usize> {
        self.replicas_of(topic, partition, k).iter().position(|(id, _)| id == node)
    }

    /// The partitions of `topic` (out of `partitions` total) this map
    /// assigns to `node`.
    pub fn owned_partitions(&self, topic: &str, partitions: usize, node: &str) -> Vec<usize> {
        (0..partitions)
            .filter(|&p| self.owner_of(topic, p).map(|(id, _)| id == node).unwrap_or(false))
            .collect()
    }

    /// A successor map over a different node set, one epoch later.
    pub fn advanced(&self, nodes: Vec<(String, String)>) -> PlacementMap {
        PlacementMap::new(self.epoch + 1, nodes)
    }

    /// Adoption order between maps (gossip anti-entropy): strictly higher
    /// epoch wins; on an epoch tie the lexicographically smaller node set
    /// wins, so every process converges on the same map no matter the
    /// gossip arrival order. Returns `true` if `other` should replace
    /// `self`.
    pub fn should_adopt(&self, other: &PlacementMap) -> bool {
        other.epoch > self.epoch || (other.epoch == self.epoch && other.nodes < self.nodes)
    }
}

/// Round-robin component placer (the in-process failure-injection sim).
pub struct Placement {
    cluster: Arc<Cluster>,
    next: AtomicUsize,
}

impl Placement {
    pub fn new(cluster: Arc<Cluster>) -> Self {
        Placement { cluster, next: AtomicUsize::new(0) }
    }

    /// Place a component on the next node in rotation; returns the node id.
    pub fn place(&self, handle: ComponentHandle) -> usize {
        let id = self.next.fetch_add(1, Ordering::Relaxed) % self.cluster.len();
        self.cluster.node(id).host(handle);
        id
    }

    /// Place on a *healthy* node if any (what Reactive Liquid's
    /// supervision does when regenerating); falls back to rotation.
    pub fn place_healthy(&self, handle: ComponentHandle) -> usize {
        let n = self.cluster.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for k in 0..n {
            let id = (start + k) % n;
            if self.cluster.node(id).is_up() {
                self.cluster.node(id).host(handle);
                return id;
            }
        }
        let id = start % n;
        self.cluster.node(id).host(handle);
        id
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noop(name: &str) -> ComponentHandle {
        ComponentHandle { name: name.into(), kill: Box::new(|| {}), respawn: Box::new(|| {}) }
    }

    fn three() -> PlacementMap {
        PlacementMap::new(
            1,
            vec![
                ("n1".into(), "addr1".into()),
                ("n2".into(), "addr2".into()),
                ("n3".into(), "addr3".into()),
            ],
        )
    }

    #[test]
    fn round_robin_balances() {
        let c = Cluster::new(3);
        let p = Placement::new(c.clone());
        for i in 0..9 {
            p.place(noop(&format!("c{i}")));
        }
        for n in c.nodes() {
            assert_eq!(n.component_count(), 3);
        }
    }

    #[test]
    fn healthy_placement_skips_down_nodes() {
        let c = Cluster::new(3);
        let p = Placement::new(c.clone());
        c.node(0).fail();
        c.node(1).fail();
        for i in 0..4 {
            let id = p.place_healthy(noop(&format!("c{i}")));
            assert_eq!(id, 2, "only node 2 is up");
        }
        assert_eq!(c.node(2).component_count(), 4);
    }

    #[test]
    fn owner_is_deterministic_and_total() {
        let m = three();
        for p in 0..64 {
            let a = m.owner_of("t", p).expect("non-empty map always owns");
            let b = m.owner_of("t", p).unwrap();
            assert_eq!(a, b);
            assert!(m.contains(&a.0));
        }
        assert!(PlacementMap::empty().owner_of("t", 0).is_none());
    }

    #[test]
    fn node_order_and_duplicates_do_not_matter() {
        let shuffled = PlacementMap::new(
            1,
            vec![
                ("n3".into(), "addr3".into()),
                ("n1".into(), "addr1".into()),
                ("n2".into(), "addr2".into()),
                ("n1".into(), "addr1".into()),
            ],
        );
        assert_eq!(three(), shuffled);
    }

    #[test]
    fn owned_partitions_partition_the_space() {
        let m = three();
        let total: usize =
            ["n1", "n2", "n3"].iter().map(|n| m.owned_partitions("t", 64, n).len()).sum();
        assert_eq!(total, 64, "every partition has exactly one owner");
    }

    #[test]
    fn hrw_golden_value_pins_process_independence() {
        // Changing the hash (or letting ambient state leak in) breaks
        // every routed cluster on a rolling upgrade — pin it.
        assert_eq!(hrw_score("n1", "t", 0), hrw_score("n1", "t", 0));
        let a = hrw_score("n1", "trajectories", 7);
        let b = hrw_score("n2", "trajectories", 7);
        assert_ne!(a, b, "distinct nodes must score distinctly");
    }

    #[test]
    fn replica_rank_zero_is_the_owner() {
        let m = three();
        for p in 0..64 {
            let replicas = m.replicas_of("t", p, DEFAULT_REPLICATION);
            assert_eq!(replicas.len(), 2);
            assert_eq!(replicas[0], m.owner_of("t", p).unwrap(), "rank 0 = primary");
            assert_ne!(replicas[0].0, replicas[1].0, "replicas are distinct nodes");
            assert_eq!(m.replica_rank("t", p, 2, &replicas[1].0), Some(1));
        }
    }

    #[test]
    fn replicas_truncate_to_cluster_size_and_k() {
        let m = three();
        assert_eq!(m.replicas_of("t", 0, 99).len(), 3, "k beyond the cluster gives everyone");
        assert_eq!(m.replicas_of("t", 0, 1).len(), 1);
        assert!(PlacementMap::empty().replicas_of("t", 0, 2).is_empty());
        // k covering all nodes ranks every node exactly once.
        let ranked: Vec<&str> = m.replicas_of("t", 5, 3).iter().map(|(id, _)| id.as_str()).collect();
        let mut sorted = ranked.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec!["n1", "n2", "n3"]);
    }

    #[test]
    fn failover_promotes_the_surviving_follower() {
        // Removing the primary from the node set must promote the old
        // rank-1 follower to rank 0 in the successor map — derivation is
        // the election.
        let m = three();
        for p in 0..64 {
            let before = m.replicas_of("t", p, 2);
            let (dead, follower) = (before[0].0.clone(), before[1].0.clone());
            let survivors =
                m.nodes().iter().filter(|(id, _)| *id != dead).cloned().collect::<Vec<_>>();
            let next = m.advanced(survivors);
            assert_eq!(next.owner_of("t", p).unwrap().0, follower, "partition {p}");
        }
    }

    #[test]
    fn adoption_prefers_higher_epoch_then_smaller_node_set() {
        let m = three();
        let newer = m.advanced(vec![("n1".into(), "addr1".into())]);
        assert!(m.should_adopt(&newer));
        assert!(!newer.should_adopt(&m));
        // Same epoch, different sets: both sides agree on one winner.
        let a = PlacementMap::new(2, vec![("a".into(), "x".into())]);
        let b = PlacementMap::new(2, vec![("b".into(), "y".into())]);
        assert!(a.should_adopt(&b) != b.should_adopt(&a));
    }
}
