//! Cluster membership fed by gossip heartbeats over the transport layer.
//!
//! Before the transport layer, the φ accrual detector was exercised with
//! *synthetic* heartbeats (components calling it directly in-process).
//! [`Membership`] is the real wiring: join/leave/heartbeat frames arrive
//! over a [`Connection`] (decoded by
//! [`GossipService`](crate::transport::gossip::GossipService)) and feed
//! the **existing** [`PhiAccrualDetector`] — so node-loss detection in a
//! multi-process deployment uses the same estimator, with the same
//! tunables and the same tests, as the in-process supervision stack.
//!
//! Semantics (deliberately small — this is a seed-node registry, not full
//! SWIM):
//!
//! - `join` registers a member (idempotent; a higher incarnation wins,
//!   so a restarted node supersedes its former self) and counts as a
//!   liveness signal;
//! - `heartbeat` from an unknown member implies a join we missed
//!   (gossip is fire-and-forget — frames may drop);
//! - `leave` removes the member *and* forgets its detector state, so a
//!   graceful departure never becomes a suspect;
//! - `suspects` = registered members whose φ exceeds the threshold.
//!
//! [`Connection`]: crate::transport::Connection

use super::placement::PlacementMap;
use crate::reactive::failure_detector::PhiAccrualDetector;
use crate::util::clock::SharedClock;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-member bookkeeping.
#[derive(Clone, Debug)]
pub struct MemberInfo {
    /// Highest incarnation observed (bumped by the member on restart).
    pub incarnation: u64,
    /// Heartbeats received from this member.
    pub heartbeats: u64,
}

/// The membership registry: who is in the cluster, and who the φ detector
/// currently suspects. All methods are callable from transport threads.
pub struct Membership {
    detector: PhiAccrualDetector,
    threshold: f64,
    members: Mutex<BTreeMap<String, MemberInfo>>,
}

impl Membership {
    /// `threshold` is the φ suspicion cutoff (8.0 is the production
    /// default in the Akka lineage this detector follows).
    pub fn new(clock: SharedClock, threshold: f64) -> Arc<Self> {
        Arc::new(Membership {
            detector: PhiAccrualDetector::new(clock, 16, Duration::from_millis(50)),
            threshold,
            members: Mutex::new(BTreeMap::new()),
        })
    }

    /// Register (or refresh) a member. Counts as a liveness signal.
    pub fn join(&self, node: &str, incarnation: u64) {
        {
            let mut m = self.members.lock().unwrap();
            let e = m
                .entry(node.to_string())
                .or_insert(MemberInfo { incarnation, heartbeats: 0 });
            if incarnation > e.incarnation {
                e.incarnation = incarnation;
            }
        }
        self.detector.heartbeat(node);
    }

    /// Graceful departure: remove the member and its detector history.
    pub fn leave(&self, node: &str) {
        self.members.lock().unwrap().remove(node);
        self.detector.forget(node);
    }

    /// Record a heartbeat (auto-joins unknown members — a dropped join
    /// frame must not make a live node invisible).
    pub fn heartbeat(&self, node: &str) {
        {
            let mut m = self.members.lock().unwrap();
            let e = m
                .entry(node.to_string())
                .or_insert(MemberInfo { incarnation: 0, heartbeats: 0 });
            e.heartbeats += 1;
        }
        self.detector.heartbeat(node);
    }

    /// Registered member ids (sorted).
    pub fn members(&self) -> Vec<String> {
        self.members.lock().unwrap().keys().cloned().collect()
    }

    pub fn member_count(&self) -> usize {
        self.members.lock().unwrap().len()
    }

    pub fn contains(&self, node: &str) -> bool {
        self.members.lock().unwrap().contains_key(node)
    }

    /// Info snapshot for one member.
    pub fn info(&self, node: &str) -> Option<MemberInfo> {
        self.members.lock().unwrap().get(node).cloned()
    }

    /// Current suspicion level of one member.
    pub fn phi(&self, node: &str) -> f64 {
        self.detector.phi(node)
    }

    /// Is this member currently past the φ threshold?
    pub fn is_suspected(&self, node: &str) -> bool {
        self.detector.is_suspected(node, self.threshold)
    }

    /// Registered members currently past the φ threshold (sorted).
    pub fn suspects(&self) -> Vec<String> {
        let members = self.members.lock().unwrap();
        self.detector
            .suspects(self.threshold)
            .into_iter()
            .filter(|n| members.contains_key(n))
            .collect()
    }
}

/// One node's view of the cluster: its [`Membership`] (who gossips, who
/// the φ detector suspects) plus the current [`PlacementMap`] and the
/// roster of every `(id, address)` ever seen in an adopted map.
///
/// This is where **failure drives rebalance**: [`ClusterView::rebalance`]
/// drops suspected members from the map, re-adds recovered roster nodes,
/// and bumps the cluster epoch — and because the successor map is a pure
/// function of the surviving node set, every node that observes the same
/// failures computes the *same* successor independently (gossip of the
/// map is anti-entropy, not consensus). The bumped epoch fences the data
/// plane: broker sessions created under the old epoch refuse polls and
/// commits ([`ErrorCode::EpochFenced`]), forcing consumers to resubscribe
/// under the new map, so a stale commit can never land after its
/// partitions moved.
///
/// A **quorum guard** keeps a partitioned minority honest: a node that
/// can only account for fewer than a strict majority of the current map's
/// members freezes (no rebalance, no epoch bump) instead of electing
/// itself a one-node cluster. On heal it adopts the majority's
/// higher-epoch map via gossip.
///
/// [`ErrorCode::EpochFenced`]: crate::transport::frame::ErrorCode
pub struct ClusterView {
    node: String,
    membership: Arc<Membership>,
    map: Mutex<PlacementMap>,
    /// Every `(id, address)` ever seen in an adopted map — suspects leave
    /// the *map* but stay here so a healed node can be re-added.
    roster: Mutex<BTreeMap<String, String>>,
}

impl ClusterView {
    pub fn new(node: &str, membership: Arc<Membership>, initial: PlacementMap) -> Arc<Self> {
        let roster = initial.nodes().iter().cloned().collect();
        Arc::new(ClusterView {
            node: node.to_string(),
            membership,
            map: Mutex::new(initial),
            roster: Mutex::new(roster),
        })
    }

    pub fn node(&self) -> &str {
        &self.node
    }

    pub fn membership(&self) -> &Arc<Membership> {
        &self.membership
    }

    pub fn epoch(&self) -> u64 {
        self.map.lock().unwrap().epoch()
    }

    /// Snapshot of the current map.
    pub fn map(&self) -> PlacementMap {
        self.map.lock().unwrap().clone()
    }

    /// Adopt `other` if it wins the [`PlacementMap::should_adopt`] order
    /// (gossip anti-entropy). Its nodes join the roster either way —
    /// an address learned from any epoch stays learnable.
    pub fn adopt(&self, other: PlacementMap) -> bool {
        {
            let mut roster = self.roster.lock().unwrap();
            for (id, addr) in other.nodes() {
                roster.entry(id.clone()).or_insert_with(|| addr.clone());
            }
        }
        let mut map = self.map.lock().unwrap();
        if map.should_adopt(&other) {
            *map = other;
            true
        } else {
            false
        }
    }

    /// Is `id` alive from this node's seat? Self is axiomatically alive;
    /// everyone else must be a registered gossip member the φ detector
    /// does not currently suspect.
    fn is_alive(&self, id: &str) -> bool {
        id == self.node || (self.membership.contains(id) && !self.membership.is_suspected(id))
    }

    /// Failure-driven rebalance tick. Computes the surviving node set
    /// (current map minus suspects, plus recovered roster nodes), and if
    /// it differs from the map's set — and this node can account for a
    /// strict majority of the *current* map (quorum guard) — installs the
    /// epoch-bumped successor and returns it for gossiping to peers.
    /// Returns `None` when nothing changed or quorum is lost.
    pub fn rebalance(&self) -> Option<PlacementMap> {
        let mut map = self.map.lock().unwrap();
        let alive_in_map =
            map.nodes().iter().filter(|(id, _)| self.is_alive(id)).count();
        // Strict majority of the map we are amending. A minority seat
        // must freeze: it cannot tell death from its own isolation.
        if !map.is_empty() && alive_in_map < map.nodes().len() / 2 + 1 {
            return None;
        }
        let roster = self.roster.lock().unwrap();
        let next: Vec<(String, String)> = roster
            .iter()
            .filter(|(id, _)| self.is_alive(id))
            .map(|(id, addr)| (id.clone(), addr.clone()))
            .collect();
        if next == map.nodes() {
            return None;
        }
        *map = map.advanced(next);
        Some(map.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    fn fixture() -> (Arc<ManualClock>, Arc<Membership>) {
        let clock = Arc::new(ManualClock::new());
        let m = Membership::new(clock.clone(), 8.0);
        (clock, m)
    }

    #[test]
    fn join_heartbeat_leave_lifecycle() {
        let (clock, m) = fixture();
        m.join("n1", 1);
        m.join("n1", 1); // idempotent
        assert_eq!(m.members(), vec!["n1".to_string()]);
        for _ in 0..10 {
            clock.advance(Duration::from_secs(1));
            m.heartbeat("n1");
        }
        assert_eq!(m.info("n1").unwrap().heartbeats, 10);
        assert!(!m.is_suspected("n1"));
        m.leave("n1");
        assert_eq!(m.member_count(), 0);
        // Silence after leave never creates a suspect.
        clock.advance(Duration::from_secs(60));
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn silent_member_becomes_suspect_and_recovers() {
        let (clock, m) = fixture();
        m.join("w", 1);
        for _ in 0..10 {
            clock.advance(Duration::from_secs(1));
            m.heartbeat("w");
        }
        clock.advance(Duration::from_secs(30));
        assert_eq!(m.suspects(), vec!["w".to_string()]);
        assert!(m.phi("w") > 8.0);
        m.heartbeat("w"); // recovery clears suspicion
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn heartbeat_from_unknown_auto_joins() {
        let (_clock, m) = fixture();
        m.heartbeat("stray");
        assert!(m.contains("stray"));
        assert_eq!(m.info("stray").unwrap().incarnation, 0);
    }

    #[test]
    fn higher_incarnation_wins() {
        let (_clock, m) = fixture();
        m.join("n", 3);
        m.join("n", 2); // stale rejoin
        assert_eq!(m.info("n").unwrap().incarnation, 3);
        m.join("n", 5); // restart
        assert_eq!(m.info("n").unwrap().incarnation, 5);
    }

    fn three_map() -> PlacementMap {
        PlacementMap::new(
            1,
            vec![
                ("n1".into(), "n1".into()),
                ("n2".into(), "n2".into()),
                ("n3".into(), "n3".into()),
            ],
        )
    }

    /// Beat every peer enough for the φ detector to build a rhythm.
    fn warm(clock: &Arc<ManualClock>, m: &Membership, peers: &[&str]) {
        for _ in 0..10 {
            clock.advance(Duration::from_secs(1));
            for p in peers {
                m.heartbeat(p);
            }
        }
    }

    #[test]
    fn suspected_node_is_rebalanced_out_and_back_in() {
        let (clock, m) = fixture();
        let view = ClusterView::new("n1", m.clone(), three_map());
        warm(&clock, &m, &["n2", "n3"]);
        assert!(view.rebalance().is_none(), "healthy cluster: no change");

        // n2 goes silent; n3 keeps beating.
        for _ in 0..30 {
            clock.advance(Duration::from_secs(1));
            m.heartbeat("n3");
        }
        assert!(m.is_suspected("n2"));
        let rebalanced = view.rebalance().expect("suspect drives a new map");
        assert_eq!(rebalanced.epoch(), 2);
        assert!(!rebalanced.contains("n2"));
        assert!(rebalanced.contains("n1") && rebalanced.contains("n3"));

        // n2 heals: heartbeats resume, the roster re-admits it.
        warm(&clock, &m, &["n2", "n3"]);
        let healed = view.rebalance().expect("recovery drives a new map");
        assert_eq!(healed.epoch(), 3);
        assert!(healed.contains("n2"));
    }

    #[test]
    fn minority_seat_freezes_instead_of_seceding() {
        let (clock, m) = fixture();
        let view = ClusterView::new("n3", m.clone(), three_map());
        warm(&clock, &m, &["n1", "n2"]);
        // n3 is isolated: from its seat, both peers go silent.
        clock.advance(Duration::from_secs(30));
        assert_eq!(m.suspects().len(), 2);
        assert!(view.rebalance().is_none(), "1 of 3 alive: below quorum, freeze");
        assert_eq!(view.epoch(), 1, "no epoch bump from a minority");
        // The majority side's higher-epoch map arrives on heal: adopted.
        let majority =
            three_map().advanced(vec![("n1".into(), "n1".into()), ("n2".into(), "n2".into())]);
        assert!(view.adopt(majority.clone()));
        assert_eq!(view.map(), majority);
        // A stale or equal-epoch echo does not regress it.
        assert!(!view.adopt(three_map()));
        assert_eq!(view.epoch(), 2);
    }

    #[test]
    fn identical_failures_yield_identical_successor_maps() {
        // Two surviving seats that observe the same suspect must compute
        // byte-identical successors without talking to each other.
        let (c1, m1) = fixture();
        let (c2, m2) = fixture();
        let v1 = ClusterView::new("n1", m1.clone(), three_map());
        let v2 = ClusterView::new("n2", m2.clone(), three_map());
        warm(&c1, &m1, &["n2", "n3"]);
        warm(&c2, &m2, &["n1", "n3"]);
        for _ in 0..30 {
            c1.advance(Duration::from_secs(1));
            m1.heartbeat("n2");
            c2.advance(Duration::from_secs(1));
            m2.heartbeat("n1");
        }
        let a = v1.rebalance().expect("n1 rebalances");
        let b = v2.rebalance().expect("n2 rebalances");
        assert_eq!(a, b, "independent seats agree on the successor map");
    }
}
