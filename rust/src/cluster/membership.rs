//! Cluster membership fed by gossip heartbeats over the transport layer.
//!
//! Before the transport layer, the φ accrual detector was exercised with
//! *synthetic* heartbeats (components calling it directly in-process).
//! [`Membership`] is the real wiring: join/leave/heartbeat frames arrive
//! over a [`Connection`] (decoded by
//! [`GossipService`](crate::transport::gossip::GossipService)) and feed
//! the **existing** [`PhiAccrualDetector`] — so node-loss detection in a
//! multi-process deployment uses the same estimator, with the same
//! tunables and the same tests, as the in-process supervision stack.
//!
//! Semantics (deliberately small — this is a seed-node registry, not full
//! SWIM):
//!
//! - `join` registers a member (idempotent; a higher incarnation wins,
//!   so a restarted node supersedes its former self) and counts as a
//!   liveness signal;
//! - `heartbeat` from an unknown member implies a join we missed
//!   (gossip is fire-and-forget — frames may drop);
//! - `leave` removes the member *and* forgets its detector state, so a
//!   graceful departure never becomes a suspect;
//! - `suspects` = registered members whose φ exceeds the threshold.
//!
//! [`Connection`]: crate::transport::Connection

use crate::reactive::failure_detector::PhiAccrualDetector;
use crate::util::clock::SharedClock;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-member bookkeeping.
#[derive(Clone, Debug)]
pub struct MemberInfo {
    /// Highest incarnation observed (bumped by the member on restart).
    pub incarnation: u64,
    /// Heartbeats received from this member.
    pub heartbeats: u64,
}

/// The membership registry: who is in the cluster, and who the φ detector
/// currently suspects. All methods are callable from transport threads.
pub struct Membership {
    detector: PhiAccrualDetector,
    threshold: f64,
    members: Mutex<BTreeMap<String, MemberInfo>>,
}

impl Membership {
    /// `threshold` is the φ suspicion cutoff (8.0 is the production
    /// default in the Akka lineage this detector follows).
    pub fn new(clock: SharedClock, threshold: f64) -> Arc<Self> {
        Arc::new(Membership {
            detector: PhiAccrualDetector::new(clock, 16, Duration::from_millis(50)),
            threshold,
            members: Mutex::new(BTreeMap::new()),
        })
    }

    /// Register (or refresh) a member. Counts as a liveness signal.
    pub fn join(&self, node: &str, incarnation: u64) {
        {
            let mut m = self.members.lock().unwrap();
            let e = m
                .entry(node.to_string())
                .or_insert(MemberInfo { incarnation, heartbeats: 0 });
            if incarnation > e.incarnation {
                e.incarnation = incarnation;
            }
        }
        self.detector.heartbeat(node);
    }

    /// Graceful departure: remove the member and its detector history.
    pub fn leave(&self, node: &str) {
        self.members.lock().unwrap().remove(node);
        self.detector.forget(node);
    }

    /// Record a heartbeat (auto-joins unknown members — a dropped join
    /// frame must not make a live node invisible).
    pub fn heartbeat(&self, node: &str) {
        {
            let mut m = self.members.lock().unwrap();
            let e = m
                .entry(node.to_string())
                .or_insert(MemberInfo { incarnation: 0, heartbeats: 0 });
            e.heartbeats += 1;
        }
        self.detector.heartbeat(node);
    }

    /// Registered member ids (sorted).
    pub fn members(&self) -> Vec<String> {
        self.members.lock().unwrap().keys().cloned().collect()
    }

    pub fn member_count(&self) -> usize {
        self.members.lock().unwrap().len()
    }

    pub fn contains(&self, node: &str) -> bool {
        self.members.lock().unwrap().contains_key(node)
    }

    /// Info snapshot for one member.
    pub fn info(&self, node: &str) -> Option<MemberInfo> {
        self.members.lock().unwrap().get(node).cloned()
    }

    /// Current suspicion level of one member.
    pub fn phi(&self, node: &str) -> f64 {
        self.detector.phi(node)
    }

    /// Is this member currently past the φ threshold?
    pub fn is_suspected(&self, node: &str) -> bool {
        self.detector.is_suspected(node, self.threshold)
    }

    /// Registered members currently past the φ threshold (sorted).
    pub fn suspects(&self) -> Vec<String> {
        let members = self.members.lock().unwrap();
        self.detector
            .suspects(self.threshold)
            .into_iter()
            .filter(|n| members.contains_key(n))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    fn fixture() -> (Arc<ManualClock>, Arc<Membership>) {
        let clock = Arc::new(ManualClock::new());
        let m = Membership::new(clock.clone(), 8.0);
        (clock, m)
    }

    #[test]
    fn join_heartbeat_leave_lifecycle() {
        let (clock, m) = fixture();
        m.join("n1", 1);
        m.join("n1", 1); // idempotent
        assert_eq!(m.members(), vec!["n1".to_string()]);
        for _ in 0..10 {
            clock.advance(Duration::from_secs(1));
            m.heartbeat("n1");
        }
        assert_eq!(m.info("n1").unwrap().heartbeats, 10);
        assert!(!m.is_suspected("n1"));
        m.leave("n1");
        assert_eq!(m.member_count(), 0);
        // Silence after leave never creates a suspect.
        clock.advance(Duration::from_secs(60));
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn silent_member_becomes_suspect_and_recovers() {
        let (clock, m) = fixture();
        m.join("w", 1);
        for _ in 0..10 {
            clock.advance(Duration::from_secs(1));
            m.heartbeat("w");
        }
        clock.advance(Duration::from_secs(30));
        assert_eq!(m.suspects(), vec!["w".to_string()]);
        assert!(m.phi("w") > 8.0);
        m.heartbeat("w"); // recovery clears suspicion
        assert!(m.suspects().is_empty());
    }

    #[test]
    fn heartbeat_from_unknown_auto_joins() {
        let (_clock, m) = fixture();
        m.heartbeat("stray");
        assert!(m.contains("stray"));
        assert_eq!(m.info("stray").unwrap().incarnation, 0);
    }

    #[test]
    fn higher_incarnation_wins() {
        let (_clock, m) = fixture();
        m.join("n", 3);
        m.join("n", 2); // stale rejoin
        assert_eq!(m.info("n").unwrap().incarnation, 3);
        m.join("n", 5); // restart
        assert_eq!(m.info("n").unwrap().incarnation, 5);
    }
}
