//! Simulated compute cluster with failure injection.
//!
//! Substitute for the paper's 3-node physical testbed (§4.3): components
//! (Liquid tasks, virtual consumers, task-pool slices) are *placed* on
//! simulated [`node`]s; the [`failure`] injector kills every node
//! independently with probability `p` at each epoch boundary (paper: every
//! 10 minutes) and brings it back after the restart delay (paper: 5
//! minutes). Killing a node invokes the kill handle of every component
//! placed on it.
//!
//! The two architectures react differently, which is exactly Fig. 10:
//!
//! - **Liquid** has no supervision — dead components return only when the
//!   *node* returns (restart delay later).
//! - **Reactive Liquid**'s supervision service detects the failures and
//!   regenerates components on healthy nodes after its (much shorter)
//!   detection delay.

pub mod failure;
pub mod membership;
pub mod node;
pub mod placement;

pub use failure::FailureInjector;
pub use membership::{ClusterView, Membership};
pub use node::{Cluster, ComponentHandle, Node};
pub use placement::{hrw_score, Placement, PlacementMap, DEFAULT_REPLICATION};
