//! Nodes and the cluster: placement targets with up/down state.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// A component placed on a node: closures to kill it (node failure) and to
/// respawn it (node recovery — Liquid-style; Reactive Liquid components
/// are *also* watched by the supervision service, which may heal them
/// earlier onto healthy nodes).
pub struct ComponentHandle {
    pub name: String,
    pub kill: Box<dyn Fn() + Send + Sync>,
    pub respawn: Box<dyn Fn() + Send + Sync>,
}

/// One simulated compute node.
pub struct Node {
    pub id: usize,
    up: AtomicBool,
    components: Mutex<Vec<ComponentHandle>>,
}

impl Node {
    pub fn new(id: usize) -> Arc<Self> {
        Arc::new(Node { id, up: AtomicBool::new(true), components: Mutex::new(Vec::new()) })
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::SeqCst)
    }

    /// Place a component on this node.
    pub fn host(&self, handle: ComponentHandle) {
        self.components.lock().unwrap().push(handle);
    }

    pub fn component_count(&self) -> usize {
        self.components.lock().unwrap().len()
    }

    /// Fail the node: mark down and kill all hosted components.
    pub fn fail(&self) {
        if !self.up.swap(false, Ordering::SeqCst) {
            return; // already down
        }
        let comps = self.components.lock().unwrap();
        for c in comps.iter() {
            (c.kill)();
        }
    }

    /// Restart the node: mark up and respawn hosted components that are
    /// still placed here.
    pub fn restart(&self) {
        if self.up.swap(true, Ordering::SeqCst) {
            return; // already up
        }
        let comps = self.components.lock().unwrap();
        for c in comps.iter() {
            (c.respawn)();
        }
    }
}

/// The cluster: a fixed set of nodes.
pub struct Cluster {
    nodes: Vec<Arc<Node>>,
}

impl Cluster {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Cluster { nodes: (0..n).map(Node::new).collect() })
    }

    pub fn nodes(&self) -> &[Arc<Node>] {
        &self.nodes
    }

    pub fn node(&self, id: usize) -> Arc<Node> {
        self.nodes[id].clone()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn up_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_up()).count()
    }

    pub fn any_up(&self) -> bool {
        self.up_count() > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn counting_handle(
        name: &str,
        kills: &Arc<AtomicUsize>,
        spawns: &Arc<AtomicUsize>,
    ) -> ComponentHandle {
        let k = kills.clone();
        let s = spawns.clone();
        ComponentHandle {
            name: name.into(),
            kill: Box::new(move || {
                k.fetch_add(1, Ordering::SeqCst);
            }),
            respawn: Box::new(move || {
                s.fetch_add(1, Ordering::SeqCst);
            }),
        }
    }

    #[test]
    fn fail_kills_components_once() {
        let kills = Arc::new(AtomicUsize::new(0));
        let spawns = Arc::new(AtomicUsize::new(0));
        let node = Node::new(0);
        node.host(counting_handle("a", &kills, &spawns));
        node.host(counting_handle("b", &kills, &spawns));
        assert!(node.is_up());
        node.fail();
        node.fail(); // idempotent
        assert!(!node.is_up());
        assert_eq!(kills.load(Ordering::SeqCst), 2);
        node.restart();
        node.restart();
        assert!(node.is_up());
        assert_eq!(spawns.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn cluster_counts() {
        let c = Cluster::new(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.up_count(), 3);
        c.node(1).fail();
        assert_eq!(c.up_count(), 2);
        assert!(c.any_up());
        c.node(0).fail();
        c.node(2).fail();
        assert!(!c.any_up());
    }
}
