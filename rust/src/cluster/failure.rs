//! Failure injector: the experiment's fault model (§4.3).
//!
//! "Every node fails after every 10 minutes working with a probability of
//! zero percent, 30 percent, 60 percent, and 90 percent. Furthermore,
//! every failed node restarts after 5 minutes." The epoch is measured per
//! node from when it (re)starts *working* — a restarted node gets a full
//! epoch of work before its next roll, not an instant re-roll at a global
//! boundary. Times are in paper minutes, compressed by `time_scale`.

use super::node::Cluster;
use crate::log_info;
use crate::sim::runtime::{ThreadTicker, TickHandle, Ticker};
use crate::util::clock::SharedClock;
use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Drives per-node epoch failures from a periodic tick — a background
/// thread in production, a discrete virtual-time event when attached to a
/// [`SimScheduler`](crate::sim::SimScheduler).
pub struct FailureInjector {
    cluster: Arc<Cluster>,
    clock: SharedClock,
    epoch: Duration,
    restart_delay: Duration,
    prob: f64,
    rng: Mutex<Pcg32>,
    running: Arc<AtomicBool>,
    tick: Mutex<Option<TickHandle>>,
    /// (node, fail_time) log for reports.
    events: Mutex<Vec<(usize, Duration)>>,
    /// Per-node schedule: when the node's next roll is due (if up) or when
    /// its restart is due (if down).
    schedule: Mutex<Vec<NodeSchedule>>,
}

#[derive(Clone, Copy, Debug)]
enum NodeSchedule {
    /// Node is up; roll the failure dice at this instant.
    RollAt(Duration),
    /// Node is down; restart it at this instant.
    RestartAt(Duration),
}

impl FailureInjector {
    pub fn new(
        cluster: Arc<Cluster>,
        clock: SharedClock,
        epoch: Duration,
        restart_delay: Duration,
        prob: f64,
        seed: u64,
    ) -> Arc<Self> {
        assert!((0.0..=1.0).contains(&prob));
        let n = cluster.len();
        Arc::new(FailureInjector {
            cluster,
            clock: clock.clone(),
            epoch,
            restart_delay,
            prob,
            rng: Mutex::new(Pcg32::new(seed)),
            running: Arc::new(AtomicBool::new(false)),
            tick: Mutex::new(None),
            events: Mutex::new(Vec::new()),
            schedule: Mutex::new(vec![NodeSchedule::RollAt(clock.now() + epoch); n]),
        })
    }

    /// One injector pass at the current clock. Deterministic; exposed for
    /// tests, driven by the thread in production.
    pub fn step(&self) {
        let now = self.clock.now();
        let mut schedule = self.schedule.lock().unwrap();
        for (id, slot) in schedule.iter_mut().enumerate() {
            match *slot {
                NodeSchedule::RollAt(due) if now >= due => {
                    let fail = self.rng.lock().unwrap().chance(self.prob);
                    if fail {
                        log_info!("failure", "node {id} failing (p={})", self.prob);
                        self.cluster.node(id).fail();
                        self.events.lock().unwrap().push((id, now));
                        *slot = NodeSchedule::RestartAt(now + self.restart_delay);
                    } else {
                        // Survived this epoch: next roll one epoch later.
                        *slot = NodeSchedule::RollAt(now + self.epoch);
                    }
                }
                NodeSchedule::RestartAt(due) if now >= due => {
                    log_info!("failure", "node {id} restarting");
                    self.cluster.node(id).restart();
                    // A full epoch of working time before the next roll.
                    *slot = NodeSchedule::RollAt(now + self.epoch);
                }
                _ => {}
            }
        }
    }

    /// Total node failures injected.
    pub fn failure_count(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn events(&self) -> Vec<(usize, Duration)> {
        self.events.lock().unwrap().clone()
    }

    /// Whether the injector thread is live (start/stop are idempotent and
    /// an injector can be restarted after a stop).
    pub fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    /// Polling granularity of the real-time injector thread.
    pub const DEFAULT_POLL: Duration = Duration::from_millis(20);

    /// Start the injector against real time (a background thread).
    pub fn start(self: &Arc<Self>) {
        self.start_on(&ThreadTicker, Self::DEFAULT_POLL);
    }

    /// Register the injector's pass with any [`Ticker`] at the given
    /// granularity — a [`ThreadTicker`] for production, a
    /// [`SimScheduler`](crate::sim::SimScheduler) for deterministic
    /// virtual-time runs. Idempotent until [`FailureInjector::stop`].
    pub fn start_on(self: &Arc<Self>, ticker: &dyn Ticker, period: Duration) {
        // The slot lock spans flag + registration so a concurrent stop()
        // either runs before this start (a no-op) or sees the handle.
        let mut slot = self.tick.lock().unwrap();
        if self.running.swap(true, Ordering::SeqCst) {
            return;
        }
        let me = self.clone();
        *slot = Some(ticker.every(
            "failure-injector",
            period,
            Box::new(move || {
                me.step();
            }),
        ));
    }

    pub fn stop(&self) {
        let mut slot = self.tick.lock().unwrap();
        self.running.store(false, Ordering::SeqCst);
        if let Some(h) = slot.take() {
            h.cancel();
        }
    }
}

impl Drop for FailureInjector {
    fn drop(&mut self) {
        // The injector thread holds its own `Arc<Self>`, so this drop can
        // only run once that thread has exited (or was never started);
        // clearing the flag here is a belt-and-braces guard for the
        // never-started case, not a substitute for `stop()`.
        self.running.store(false, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    fn fixture(prob: f64) -> (Arc<ManualClock>, Arc<Cluster>, Arc<FailureInjector>) {
        let clock = Arc::new(ManualClock::new());
        let cluster = Cluster::new(3);
        let inj = FailureInjector::new(
            cluster.clone(),
            clock.clone(),
            Duration::from_secs(10),
            Duration::from_secs(5),
            prob,
            7,
        );
        (clock, cluster, inj)
    }

    #[test]
    fn zero_probability_never_fails() {
        let (clock, cluster, inj) = fixture(0.0);
        for _ in 0..20 {
            clock.advance(Duration::from_secs(10));
            inj.step();
        }
        assert_eq!(inj.failure_count(), 0);
        assert_eq!(cluster.up_count(), 3);
    }

    #[test]
    fn certain_probability_fails_then_restarts_with_working_window() {
        let (clock, cluster, inj) = fixture(1.0);
        clock.advance(Duration::from_secs(10));
        inj.step();
        assert_eq!(inj.failure_count(), 3, "all nodes down at their epoch");
        assert_eq!(cluster.up_count(), 0);
        // Before restart delay: still down.
        clock.advance(Duration::from_secs(4));
        inj.step();
        assert_eq!(cluster.up_count(), 0);
        // After restart delay: all back — and they STAY up for a full
        // working epoch before the next roll (no instant re-fail).
        clock.advance(Duration::from_secs(1));
        inj.step();
        assert_eq!(cluster.up_count(), 3);
        clock.advance(Duration::from_secs(9)); // 9 < epoch since restart
        inj.step();
        assert_eq!(cluster.up_count(), 3, "full working epoch honoured");
        clock.advance(Duration::from_secs(1)); // epoch complete
        inj.step();
        assert_eq!(cluster.up_count(), 0, "next roll fails again at p=1");
        assert_eq!(inj.failure_count(), 6);
    }

    #[test]
    fn mid_epoch_nothing_happens() {
        let (clock, cluster, inj) = fixture(1.0);
        clock.advance(Duration::from_secs(3));
        inj.step();
        assert_eq!(cluster.up_count(), 3, "mid-epoch: nothing happens");
        assert_eq!(inj.failure_count(), 0);
    }

    #[test]
    fn start_stop_idempotent_and_restartable() {
        let (_clock, _cluster, inj) = fixture(0.0);
        assert!(!inj.is_running());
        inj.start();
        inj.start(); // idempotent
        assert!(inj.is_running());
        inj.stop();
        assert!(!inj.is_running());
        inj.start(); // restartable after stop
        assert!(inj.is_running());
        inj.stop();
    }

    #[test]
    fn injector_on_sim_scheduler_is_deterministic() {
        let run = || {
            let sched = crate::sim::SimScheduler::new(1);
            let cluster = Cluster::new(3);
            let inj = FailureInjector::new(
                cluster,
                sched.clock(),
                Duration::from_secs(10),
                Duration::from_secs(5),
                0.5,
                99,
            );
            inj.start_on(&sched, Duration::from_secs(1));
            sched.run_until(Duration::from_secs(200));
            inj.stop();
            inj.events()
        };
        let a = run();
        assert_eq!(a, run(), "same seed, same virtual-time failure schedule");
        assert!(!a.is_empty(), "p=0.5 over ~20 epochs × 3 nodes fires");
    }

    #[test]
    fn probabilistic_rate_reasonable() {
        // ~30% per node per epoch over many epochs.
        let (clock, _cluster, inj) = fixture(0.3);
        let mut rolls = 0;
        for _ in 0..400 {
            clock.advance(Duration::from_secs(5));
            inj.step();
        }
        // Count total roll opportunities: nodes alternate 10s-up epochs
        // and (on failure) 5s downtime; lower-bound the rolls by the
        // no-failure case and upper-bound via events.
        // 400 * 5s = 2000s; per node: between 2000/15 and 2000/10 rolls.
        let lo = 3.0 * 2000.0 / 15.0;
        let hi = 3.0 * 2000.0 / 10.0;
        rolls += inj.failure_count();
        let rate_hi = rolls as f64 / lo;
        let rate_lo = rolls as f64 / hi;
        assert!(
            rate_lo < 0.45 && rate_hi > 0.15,
            "failure rate bracket [{rate_lo:.2}, {rate_hi:.2}] should straddle 0.3"
        );
    }
}
