//! Asynchronous messaging layer — a thread-backed actor runtime
//! (the paper's §3.2.4; substitute for Akka).
//!
//! Provides exactly the reactive-manifesto properties the paper relies on:
//!
//! - **message-driven**: components communicate only through typed,
//!   depth-instrumented [`Mailbox`]es (the elastic-worker service scales on
//!   mailbox depth, §3.2.2);
//! - **isolation**: each actor runs on its own thread; a panic is contained
//!   to the actor, reported to failure hooks, and never unwinds into
//!   neighbours (let-it-crash);
//! - **location transparency**: [`ActorRef`] is a clonable address; senders
//!   cannot tell where (which thread / simulated node) the actor runs, and
//!   a restarted actor keeps its address *and* its unprocessed mailbox;
//! - **flow control**: mailboxes are bounded; `tell` applies backpressure,
//!   `try_tell` surfaces overload to the caller.
//!
//! Supervision *policy* lives in [`crate::reactive::supervision`]; this
//! module only exposes the mechanism (failure hooks + [`ActorSystem::restart`]).

pub mod ask;
pub mod deadletter;
pub mod mailbox;
pub mod system;

pub use ask::{ask, Reply};
pub use deadletter::DeadLetters;
pub use mailbox::{Mailbox, RecvError, SendError};
pub use system::{Actor, ActorRef, ActorSystem, Ctx};
