//! Asynchronous messaging layer — an executor-backed actor runtime
//! (the paper's §3.2.4; substitute for Akka).
//!
//! Provides exactly the reactive-manifesto properties the paper relies on:
//!
//! - **message-driven**: components communicate only through typed,
//!   depth-instrumented [`Mailbox`]es (the elastic-worker service scales on
//!   mailbox depth, §3.2.2);
//! - **isolation**: each actor is a poll-driven state machine multiplexed
//!   over the [`executor`]'s fixed worker pool; a panic is contained to
//!   the actor, reported to failure hooks, and never unwinds into
//!   neighbours (let-it-crash). Actor count is decoupled from OS threads:
//!   10k actors run on `available_parallelism` workers plus one timer
//!   thread;
//! - **location transparency**: [`ActorRef`] is a clonable address; senders
//!   cannot tell where (which worker / simulated node) the actor runs, and
//!   a restarted actor keeps its address *and* its unprocessed mailbox;
//! - **flow control**: mailboxes are bounded; `tell` applies backpressure,
//!   `try_tell` surfaces overload to the caller, and a backpressured actor
//!   parks via [`Ctx::defer`] + the executor timer instead of blocking a
//!   worker thread. Closed-mailbox rejects aggregate into the system's
//!   [`DeadLetters`].
//!
//! Supervision *policy* lives in [`crate::reactive::supervision`]; this
//! module only exposes the mechanism (failure hooks + [`ActorSystem::restart`],
//! which re-arms the actor's existing executor registration instead of
//! respawning a thread).

pub mod ask;
pub mod deadletter;
pub mod executor;
pub mod mailbox;
pub mod system;

pub use ask::{ask, Reply};
pub use deadletter::DeadLetters;
pub use executor::{Activation, Executor, Poll, Poller, ThreadedExecutor};
pub use mailbox::{Mailbox, RecvError, SendError};
pub use system::{Actor, ActorRef, ActorSystem, Ctx};
