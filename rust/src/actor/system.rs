//! Actor trait, references, and the system that hosts actors on the
//! executor's worker pool.
//!
//! Since the executor refactor an actor no longer owns an OS thread: each
//! spawned actor is a [`TypedCell`]-backed [`Poller`] registered with the
//! system's [`Executor`]. Message arrival flips the cell's activation
//! flag (one CAS) and a pool worker drives the actor for up to one
//! message budget; restarts re-register nothing — the same activation is
//! re-armed with a fresh actor instance, so the let-it-crash cycle costs
//! an allocation instead of a thread spawn/join.

use super::deadletter::DeadLetters;
use super::executor::{
    Executor, Poll, Poller, Registration, ThreadedExecutor, DEFAULT_BUDGET,
};
use super::mailbox::{Mailbox, RecvError, SendError};
use crate::log_debug;
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

/// A typed actor. Implementations are plain structs; a fresh instance is
/// built by the spawn factory on every (re)start — the let-it-crash pattern
/// wipes in-memory state, and stateful actors recover via the state
/// management service (event sourcing), exactly as §2.2 prescribes.
pub trait Actor: Send + 'static {
    type Msg: Send + 'static;

    /// Called once per (re)start before the first message.
    fn pre_start(&mut self, _ctx: &mut Ctx<Self::Msg>) {}

    /// Called at the start of every activation, before any message is
    /// consumed. Actors holding internal buffers (e.g. unflushed output
    /// under downstream backpressure) flush here and may
    /// [`Ctx::defer`] without consuming their mailbox.
    fn on_activate(&mut self, _ctx: &mut Ctx<Self::Msg>) {}

    /// Handle one message. Panicking here marks the actor failed and
    /// triggers the system's failure hooks (supervision).
    fn receive(&mut self, msg: Self::Msg, ctx: &mut Ctx<Self::Msg>);

    /// Called on graceful stop (not on panic).
    fn post_stop(&mut self) {}
}

/// Execution context handed to the actor.
pub struct Ctx<M: Send + 'static> {
    /// This actor's own address.
    pub self_ref: ActorRef<M>,
    /// Restart count (0 on first incarnation).
    pub incarnation: u64,
    stop: bool,
    defer: Option<Duration>,
}

impl<M: Send + 'static> Ctx<M> {
    /// Ask the runtime to stop this actor after the current message.
    pub fn stop(&mut self) {
        self.stop = true;
    }

    /// Pause this actor: end the activation now and re-activate after
    /// `delay` (or sooner, if a new message arrives). Used for
    /// backpressure — the mailbox is left untouched and no worker thread
    /// blocks while waiting.
    pub fn defer(&mut self, delay: Duration) {
        self.defer = Some(delay);
    }
}

/// Clonable, location-transparent actor address.
pub struct ActorRef<M> {
    pub path: Arc<String>,
    mailbox: Arc<Mailbox<M>>,
    dead: Option<Arc<DeadLetters>>,
}

impl<M> Clone for ActorRef<M> {
    fn clone(&self) -> Self {
        ActorRef {
            path: self.path.clone(),
            mailbox: self.mailbox.clone(),
            dead: self.dead.clone(),
        }
    }
}

impl<M: Send + 'static> ActorRef<M> {
    fn record_dead(&self) {
        if let Some(dl) = &self.dead {
            dl.record(&self.path);
        }
    }

    /// Fire-and-forget with backpressure (blocks while the mailbox is
    /// full). A closed-mailbox reject loses the message and is recorded
    /// in the system's [`DeadLetters`].
    ///
    /// **Do not call from inside an actor toward a possibly-saturated
    /// target**: blocking parks a carrier thread, and if every worker
    /// blocks this way the fixed pool livelocks (the thread-per-actor
    /// model could not deadlock like this). Actors should use
    /// [`ActorRef::try_tell_back`] plus [`Ctx::defer`] instead; blocking
    /// sends are for code running outside the executor (ingest, tests,
    /// examples).
    pub fn tell(&self, msg: M) -> Result<(), SendError> {
        let r = self.mailbox.send(msg);
        if r == Err(SendError::Closed) {
            self.record_dead();
        }
        r
    }

    /// Blocking send that returns the message on failure (closed
    /// mailbox). Not counted as a dead letter: the caller keeps the
    /// message and decides its fate (re-route, buffer, or drop). The
    /// same carrier-thread warning as [`ActorRef::tell`] applies.
    pub fn tell_back(&self, msg: M) -> Result<(), (SendError, M)> {
        self.mailbox.send_back(msg)
    }

    /// Bounded-blocking send: waits up to `timeout` for mailbox space,
    /// then hands the message back with `Full` so the caller can re-try
    /// other targets. Not counted as a dead letter.
    pub fn tell_back_timeout(&self, msg: M, timeout: Duration) -> Result<(), (SendError, M)> {
        self.mailbox.send_back_timeout(msg, timeout)
    }

    /// Non-blocking send. A closed-mailbox reject loses the message and
    /// is recorded in the system's [`DeadLetters`].
    pub fn try_tell(&self, msg: M) -> Result<(), SendError> {
        let r = self.mailbox.try_send(msg);
        if r == Err(SendError::Closed) {
            self.record_dead();
        }
        r
    }

    /// Non-blocking send that returns the message on failure (no clone
    /// needed by callers that want to redirect it). Not counted as a
    /// dead letter — routers and batch publishers spill rejected
    /// messages to their next live target, so only a sender that *loses*
    /// a message (the non-`_back` variants) marks a drop.
    pub fn try_tell_back(&self, msg: M) -> Result<(), (SendError, M)> {
        self.mailbox.try_send_back(msg)
    }

    /// Mailbox depth — the signal the elastic-worker service scales on.
    pub fn mailbox_depth(&self) -> usize {
        self.mailbox.depth()
    }

    pub fn is_closed(&self) -> bool {
        self.mailbox.is_closed()
    }
}

/// Internal control handle for one hosted actor (type-erased).
trait Cell: Send + Sync {
    fn stop(&self);
    /// Crash semantics: discard queued messages, then stop.
    fn crash(&self);
    /// Wait up to `timeout` until the actor has wound down (executor
    /// workers drive the drain — including deferred flush retries toward
    /// a backpressured downstream; a zero timeout — cooperative
    /// executors — returns immediately).
    fn join(&self, timeout: Duration);
    fn is_running(&self) -> bool;
    fn mailbox_depth(&self) -> usize;
    /// (Re)arm the cell: fresh instance on next activation, same path,
    /// same mailbox, same executor registration.
    fn launch(&self);
}

/// Actor lifecycle within its cell. `Fresh` builds a new instance on the
/// next activation; `Stopped` stays inert until `launch` re-arms it.
enum CellState<A: Actor> {
    Fresh,
    Live { actor: A, incarnation: u64 },
    Stopped,
}

/// What one activation decided (computed under the state lock, applied
/// and reported after it is released).
enum Outcome {
    Poll(Poll),
    Stopped,
    Crashed,
}

struct TypedCell<A: Actor> {
    path: Arc<String>,
    mailbox: Arc<Mailbox<A::Msg>>,
    factory: Box<dyn Fn() -> A + Send + Sync>,
    running: AtomicBool,
    incarnation: AtomicU64,
    hooks: FailureHooks,
    dead: Arc<DeadLetters>,
    state: Mutex<CellState<A>>,
    registration: Registration,
}

type FailureHooks = Arc<RwLock<Vec<Box<dyn Fn(&str) + Send + Sync>>>>;

impl<A: Actor> TypedCell<A> {
    fn self_ref(&self) -> ActorRef<A::Msg> {
        ActorRef {
            path: self.path.clone(),
            mailbox: self.mailbox.clone(),
            dead: Some(self.dead.clone()),
        }
    }

    /// Flip `running` off and wake joiners.
    fn mark_down(&self) {
        self.running.store(false, Ordering::SeqCst);
        self.registration.wake_joiners();
    }

    /// Drive one live actor instance for up to `budget` messages.
    fn drive(&self, actor: &mut A, incarnation: u64, budget: usize) -> Outcome {
        let mut ctx = Ctx {
            self_ref: self.self_ref(),
            incarnation,
            stop: false,
            defer: None,
        };
        if std::panic::catch_unwind(AssertUnwindSafe(|| actor.on_activate(&mut ctx))).is_err() {
            return Outcome::Crashed;
        }
        if ctx.stop {
            let _ = std::panic::catch_unwind(AssertUnwindSafe(|| actor.post_stop()));
            return Outcome::Stopped;
        }
        if let Some(d) = ctx.defer {
            return Outcome::Poll(Poll::After(d));
        }
        let mut used = 0;
        while used < budget {
            match self.mailbox.try_recv() {
                Ok(msg) => {
                    used += 1;
                    if std::panic::catch_unwind(AssertUnwindSafe(|| actor.receive(msg, &mut ctx)))
                        .is_err()
                    {
                        return Outcome::Crashed;
                    }
                    if ctx.stop {
                        let _ = std::panic::catch_unwind(AssertUnwindSafe(|| actor.post_stop()));
                        return Outcome::Stopped;
                    }
                    if let Some(d) = ctx.defer {
                        return Outcome::Poll(Poll::After(d));
                    }
                }
                Err(RecvError::Empty) | Err(RecvError::Timeout) => {
                    return Outcome::Poll(Poll::Idle);
                }
                Err(RecvError::Closed) => {
                    let _ = std::panic::catch_unwind(AssertUnwindSafe(|| actor.post_stop()));
                    return Outcome::Stopped;
                }
            }
        }
        // Budget exhausted with (possibly) more queued: yield fairly.
        Outcome::Poll(Poll::Ready)
    }
}

impl<A: Actor> Poller for TypedCell<A> {
    fn poll(&self, budget: usize) -> Poll {
        if !self.running.load(Ordering::SeqCst) {
            return Poll::Idle; // crashed/stopped: inert until launch()
        }
        let outcome = {
            let mut state = self.state.lock().unwrap();
            if let CellState::Fresh = &*state {
                let incarnation = self.incarnation.fetch_add(1, Ordering::SeqCst);
                let mut actor = match std::panic::catch_unwind(AssertUnwindSafe(|| (self.factory)())) {
                    Ok(a) => a,
                    Err(_) => {
                        drop(state);
                        self.mark_down();
                        self.fire_hooks();
                        return Poll::Idle;
                    }
                };
                let mut ctx = Ctx {
                    self_ref: self.self_ref(),
                    incarnation,
                    stop: false,
                    defer: None,
                };
                if std::panic::catch_unwind(AssertUnwindSafe(|| actor.pre_start(&mut ctx)))
                    .is_err()
                {
                    drop(state);
                    self.mark_down();
                    self.fire_hooks();
                    return Poll::Idle;
                }
                if ctx.stop {
                    let _ = std::panic::catch_unwind(AssertUnwindSafe(|| actor.post_stop()));
                    *state = CellState::Stopped;
                    drop(state);
                    self.mark_down();
                    return Poll::Idle;
                }
                let deferred = ctx.defer;
                *state = CellState::Live { actor, incarnation };
                if let Some(d) = deferred {
                    // pre_start deferred: pause before the first message,
                    // same contract as defer from on_activate/receive.
                    return Poll::After(d);
                }
            }
            match &mut *state {
                CellState::Live { actor, incarnation } => {
                    let incarnation = *incarnation;
                    let outcome = self.drive(actor, incarnation, budget);
                    match &outcome {
                        Outcome::Stopped => *state = CellState::Stopped,
                        // Let-it-crash: drop the instance; a later
                        // launch() builds a fresh one.
                        Outcome::Crashed => *state = CellState::Fresh,
                        Outcome::Poll(_) => {}
                    }
                    outcome
                }
                CellState::Stopped => Outcome::Poll(Poll::Idle),
                CellState::Fresh => unreachable!("Fresh handled above"),
            }
        };
        match outcome {
            Outcome::Poll(p) => p,
            Outcome::Stopped => {
                self.mark_down();
                Poll::Idle
            }
            Outcome::Crashed => {
                log_debug!(
                    "actor",
                    "'{}' crashed (incarnation {})",
                    self.path,
                    self.incarnation.load(Ordering::SeqCst).saturating_sub(1)
                );
                // The mailbox stays open so queued and in-flight messages
                // survive the restart.
                self.mark_down();
                self.fire_hooks();
                Poll::Idle
            }
        }
    }

    fn path(&self) -> &str {
        &self.path
    }
}

impl<A: Actor> TypedCell<A> {
    fn fire_hooks(&self) {
        let hooks = self.hooks.read().unwrap();
        for hook in hooks.iter() {
            hook(&self.path);
        }
    }
}

impl<A: Actor> Cell for TypedCell<A> {
    fn stop(&self) {
        // close() signals the activation, which drains then stops.
        self.mailbox.close();
    }

    fn crash(&self) {
        self.mailbox.close(); // stop accepting first…
        self.mailbox.purge(); // …then drop what was queued
    }

    fn join(&self, timeout: Duration) {
        self.registration
            .join_while(|| self.running.load(Ordering::SeqCst), timeout);
    }

    fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    fn mailbox_depth(&self) -> usize {
        self.mailbox.depth()
    }

    fn launch(&self) {
        *self.state.lock().unwrap() = CellState::Fresh;
        self.mailbox.reopen();
        self.running.store(true, Ordering::SeqCst);
        self.registration.notify();
    }
}

/// The actor system: spawns actors onto the executor's worker pool,
/// tracks them by path, reports failures to registered hooks, and
/// restarts failed actors in place (same path, same mailbox, same
/// executor registration).
pub struct ActorSystem {
    executor: Arc<dyn Executor>,
    owns_executor: bool,
    /// How long stop/remove/kill wait for a cell to wind down. Zero for
    /// cooperative executors: the sim backend only makes progress when
    /// its scheduler is pumped, so waiting would stall.
    join_wait: Duration,
    cells: RwLock<HashMap<String, Arc<dyn Cell>>>,
    /// Cells removed (or replaced) before their drain finished. The
    /// executor holds only weak refs, so something must keep a
    /// mid-drain cell alive until its close-drain activation completes —
    /// without this, `remove` on a cooperative executor (or after a
    /// join timeout) would drop queued messages and skip `post_stop`.
    graveyard: Mutex<Vec<Arc<dyn Cell>>>,
    hooks: FailureHooks,
    dead: Arc<DeadLetters>,
}

impl ActorSystem {
    /// System on its own work-stealing executor sized to the host
    /// (one worker per core).
    pub fn new() -> Arc<Self> {
        Self::build(ThreadedExecutor::with_default_parallelism(), true)
    }

    /// System on its own executor with an explicit worker count — size
    /// this for workloads whose actors *block* (e.g. synthetic
    /// processing-cost sleeps in the experiment harness).
    pub fn with_workers(workers: usize) -> Arc<Self> {
        Self::build(ThreadedExecutor::new(workers), true)
    }

    /// System on a shared executor (e.g. the deterministic
    /// [`SimExecutor`](crate::sim::SimExecutor)). The executor is not
    /// shut down by [`ActorSystem::shutdown`], and stop/remove/kill do
    /// **not** wait for the wind-down — drive the executor (pump the
    /// scheduler) to complete drains.
    pub fn with_executor(executor: Arc<dyn Executor>) -> Arc<Self> {
        Self::build(executor, false)
    }

    fn build(executor: Arc<dyn Executor>, owns_executor: bool) -> Arc<Self> {
        // Graceful drains must complete: the bound covers the worst
        // legitimate drain (a full mailbox of the slowest synthetic-cost
        // processors, ~13 s) with an order of magnitude of headroom. It
        // exists only as a safety valve for a pathologically dead
        // downstream — a case where the pre-executor thread join hung
        // forever.
        let join_wait =
            if executor.is_cooperative() { Duration::ZERO } else { Duration::from_secs(120) };
        Arc::new(ActorSystem {
            executor,
            owns_executor,
            join_wait,
            cells: RwLock::new(HashMap::new()),
            graveyard: Mutex::new(Vec::new()),
            hooks: Arc::new(RwLock::new(Vec::new())),
            dead: Arc::new(DeadLetters::new()),
        })
    }

    /// Keep a forgotten-but-still-draining cell alive until its
    /// wind-down activation runs; already-drained graveyard entries are
    /// swept opportunistically.
    fn bury(&self, cell: Arc<dyn Cell>) {
        let mut g = self.graveyard.lock().unwrap();
        g.retain(|c| c.is_running());
        if cell.is_running() {
            g.push(cell);
        }
    }

    /// The executor this system schedules actors on.
    pub fn executor(&self) -> Arc<dyn Executor> {
        self.executor.clone()
    }

    /// System-wide dead-letter aggregation: every closed-mailbox
    /// `tell`/`try_tell` reject is recorded here by actor path.
    pub fn dead_letters(&self) -> Arc<DeadLetters> {
        self.dead.clone()
    }

    /// Register a failure hook: called with the actor path whenever an
    /// actor panics. The supervision service registers itself here.
    pub fn on_failure(&self, hook: impl Fn(&str) + Send + Sync + 'static) {
        self.hooks.write().unwrap().push(Box::new(hook));
    }

    /// Spawn an actor. `factory` builds a fresh instance per incarnation.
    pub fn spawn<A: Actor>(
        self: &Arc<Self>,
        path: &str,
        capacity: usize,
        factory: impl Fn() -> A + Send + Sync + 'static,
    ) -> ActorRef<A::Msg> {
        let cell = Arc::new(TypedCell {
            path: Arc::new(path.to_string()),
            mailbox: Arc::new(Mailbox::new(capacity)),
            factory: Box::new(factory),
            running: AtomicBool::new(false),
            incarnation: AtomicU64::new(0),
            hooks: self.hooks.clone(),
            dead: self.dead.clone(),
            state: Mutex::new(CellState::Fresh),
            registration: Registration::new(),
        });
        let act = self.executor.register(cell.clone(), DEFAULT_BUDGET);
        cell.registration.arm(act.clone());
        // Message arrival (and close) schedules an activation: one CAS on
        // the schedule flag, no condvar in the hot path. The signal holds
        // the activation strongly — no cycle, since the activation only
        // holds a Weak back to the cell.
        cell.mailbox.set_signal(move || act.notify());
        cell.launch();
        let r = cell.self_ref();
        let replaced = self.cells.write().unwrap().insert(path.to_string(), cell);
        if let Some(old) = replaced {
            // Re-spawning an existing path orphans the old actor: close
            // its mailbox so stale refs fail fast instead of filling a
            // never-drained queue, and keep it alive until its drain
            // completes.
            old.stop();
            self.bury(old);
        }
        r
    }

    /// Restart a failed (or stopped) actor in place: fresh instance, same
    /// path, same mailbox, same executor registration. No-op if it is
    /// still running or unknown.
    pub fn restart(&self, path: &str) -> bool {
        let cell = self.cells.read().unwrap().get(path).cloned();
        match cell {
            Some(c) if !c.is_running() => {
                c.launch();
                true
            }
            _ => false,
        }
    }

    /// True if the actor exists and is live on the executor.
    pub fn is_running(&self, path: &str) -> bool {
        self.cells.read().unwrap().get(path).map(|c| c.is_running()).unwrap_or(false)
    }

    pub fn mailbox_depth(&self, path: &str) -> Option<usize> {
        self.cells.read().unwrap().get(path).map(|c| c.mailbox_depth())
    }

    /// Stop one actor (graceful: drains mailbox, runs `post_stop`).
    pub fn stop(&self, path: &str) {
        let cell = self.cells.read().unwrap().get(path).cloned();
        if let Some(c) = cell {
            c.stop();
            c.join(self.join_wait);
        }
    }

    /// Remove an actor entirely (graceful stop + forget: queued messages
    /// are processed first — a cell still draining when the bounded join
    /// returns is kept alive off-map until its drain completes). Its
    /// `ActorRef`s go dead.
    pub fn remove(&self, path: &str) {
        self.stop(path);
        if let Some(c) = self.cells.write().unwrap().remove(path) {
            self.bury(c);
        }
    }

    /// Kill an actor as if its host died: queued messages are DROPPED,
    /// the in-flight message (if any) finishes (an activation cannot be
    /// torn mid-message), then the actor is forgotten.
    pub fn kill(&self, path: &str) {
        let cell = self.cells.read().unwrap().get(path).cloned();
        if let Some(c) = cell {
            c.crash();
            c.join(self.join_wait);
        }
        if let Some(c) = self.cells.write().unwrap().remove(path) {
            self.bury(c);
        }
    }

    /// All registered actor paths.
    pub fn paths(&self) -> Vec<String> {
        self.cells.read().unwrap().keys().cloned().collect()
    }

    /// Stop every actor (graceful), then the executor if this system owns
    /// it.
    pub fn shutdown(&self) {
        let mut cells: Vec<Arc<dyn Cell>> = self.cells.read().unwrap().values().cloned().collect();
        cells.extend(self.graveyard.lock().unwrap().iter().cloned());
        for c in &cells {
            c.stop();
        }
        for c in &cells {
            c.join(self.join_wait);
        }
        if self.owns_executor {
            self.executor.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wait_until;
    use std::sync::atomic::AtomicUsize;

    struct Counter {
        hits: Arc<AtomicUsize>,
    }

    impl Actor for Counter {
        type Msg = u32;

        fn receive(&mut self, msg: u32, ctx: &mut Ctx<u32>) {
            if msg == u32::MAX {
                ctx.stop();
                return;
            }
            if msg == 666 {
                panic!("poison message");
            }
            self.hits.fetch_add(msg as usize, Ordering::SeqCst);
        }
    }

    #[test]
    fn processes_messages() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("counter", 64, move || Counter { hits: h.clone() });
        for _ in 0..10 {
            r.tell(2).unwrap();
        }
        assert!(wait_until(|| hits.load(Ordering::SeqCst) == 20, Duration::from_secs(2)));
        sys.shutdown();
    }

    #[test]
    fn panic_is_contained_and_hooked() {
        let sys = ActorSystem::new();
        let failed = Arc::new(Mutex::new(Vec::<String>::new()));
        let f = failed.clone();
        sys.on_failure(move |path| f.lock().unwrap().push(path.to_string()));
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("fragile", 64, move || Counter { hits: h.clone() });
        r.tell(666).unwrap();
        assert!(wait_until(|| !sys.is_running("fragile"), Duration::from_secs(2)));
        assert_eq!(failed.lock().unwrap().as_slice(), &["fragile".to_string()]);
        sys.shutdown();
    }

    #[test]
    fn restart_keeps_address_and_mailbox() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("phoenix", 64, move || Counter { hits: h.clone() });
        r.tell(666).unwrap(); // crash
        assert!(wait_until(|| !sys.is_running("phoenix"), Duration::from_secs(2)));
        // Queue messages while down — the mailbox survives.
        r.tell(5).unwrap();
        r.tell(7).unwrap();
        assert!(sys.restart("phoenix"));
        assert!(wait_until(|| hits.load(Ordering::SeqCst) == 12, Duration::from_secs(2)));
        sys.shutdown();
    }

    #[test]
    fn repeated_crash_restart_cycles_rearm_the_same_registration() {
        // The executor-era restart path: no thread respawn, the same
        // activation is re-armed. Crash and heal several times in a row.
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("cycler", 64, move || Counter { hits: h.clone() });
        for round in 1..=3 {
            r.tell(666).unwrap();
            assert!(wait_until(|| !sys.is_running("cycler"), Duration::from_secs(2)));
            assert!(sys.restart("cycler"));
            assert!(wait_until(|| sys.is_running("cycler"), Duration::from_secs(2)));
            r.tell(1).unwrap();
            assert!(
                wait_until(|| hits.load(Ordering::SeqCst) == round, Duration::from_secs(2)),
                "round {round}: hits {}",
                hits.load(Ordering::SeqCst)
            );
        }
        sys.shutdown();
    }

    #[test]
    fn restart_noop_when_running() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        sys.spawn("alive", 8, move || Counter { hits: h.clone() });
        assert!(wait_until(|| sys.is_running("alive"), Duration::from_secs(1)));
        assert!(!sys.restart("alive"));
        assert!(!sys.restart("nonexistent"));
        sys.shutdown();
    }

    #[test]
    fn ctx_stop_runs_post_stop_and_exits() {
        struct Stopper {
            stopped: Arc<AtomicUsize>,
        }
        impl Actor for Stopper {
            type Msg = ();
            fn receive(&mut self, _m: (), ctx: &mut Ctx<()>) {
                ctx.stop();
            }
            fn post_stop(&mut self) {
                self.stopped.fetch_add(1, Ordering::SeqCst);
            }
        }
        let sys = ActorSystem::new();
        let stopped = Arc::new(AtomicUsize::new(0));
        let s = stopped.clone();
        let r = sys.spawn("stopper", 8, move || Stopper { stopped: s.clone() });
        r.tell(()).unwrap();
        assert!(wait_until(|| stopped.load(Ordering::SeqCst) == 1, Duration::from_secs(2)));
        assert!(wait_until(|| !sys.is_running("stopper"), Duration::from_secs(2)));
        sys.shutdown();
    }

    #[test]
    fn kill_drops_queued_messages_remove_drains_them() {
        // Two identical slow actors with queued work: `remove` (graceful)
        // processes the queue, `kill` (crash) drops it.
        struct Slow {
            hits: Arc<AtomicUsize>,
        }
        impl Actor for Slow {
            type Msg = ();
            fn receive(&mut self, _m: (), _ctx: &mut Ctx<()>) {
                std::thread::sleep(Duration::from_millis(5));
                self.hits.fetch_add(1, Ordering::SeqCst);
            }
        }
        let sys = ActorSystem::new();
        let graceful_hits = Arc::new(AtomicUsize::new(0));
        let crashed_hits = Arc::new(AtomicUsize::new(0));
        let g = graceful_hits.clone();
        let c = crashed_hits.clone();
        let gr = sys.spawn("graceful", 64, move || Slow { hits: g.clone() });
        let cr = sys.spawn("crashed", 64, move || Slow { hits: c.clone() });
        for _ in 0..20 {
            gr.tell(()).unwrap();
            cr.tell(()).unwrap();
        }
        // Kill FIRST (before the graceful drain gives the other actor
        // 100ms to chew through its queue on a small host).
        sys.kill("crashed"); // drops the queue
        sys.remove("graceful"); // drains all 20
        assert_eq!(graceful_hits.load(Ordering::SeqCst), 20);
        assert!(
            crashed_hits.load(Ordering::SeqCst) < 20,
            "crash must drop queued work, processed {}",
            crashed_hits.load(Ordering::SeqCst)
        );
        sys.shutdown();
    }

    #[test]
    fn remove_kills_address() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("gone", 8, move || Counter { hits: h.clone() });
        sys.remove("gone");
        assert!(r.tell(1).is_err());
        assert!(sys.mailbox_depth("gone").is_none());
    }

    #[test]
    fn closed_mailbox_rejects_feed_dead_letters() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("dl", 8, move || Counter { hits: h.clone() });
        sys.remove("dl");
        assert!(r.tell(1).is_err());
        assert!(r.try_tell(2).is_err());
        assert_eq!(sys.dead_letters().count("dl"), 2);
        assert_eq!(sys.dead_letters().total(), 2);
        sys.shutdown();
    }

    #[test]
    fn deferred_actor_resumes_after_deadline_without_consuming() {
        // An actor that defers on activation until released: its queued
        // message stays in the mailbox (backpressure without blocking a
        // worker), then is consumed after release.
        struct Deferring {
            release: Arc<AtomicBool>,
            hits: Arc<AtomicUsize>,
        }
        impl Actor for Deferring {
            type Msg = u32;
            fn on_activate(&mut self, ctx: &mut Ctx<u32>) {
                if !self.release.load(Ordering::SeqCst) {
                    ctx.defer(Duration::from_millis(2));
                }
            }
            fn receive(&mut self, _m: u32, _ctx: &mut Ctx<u32>) {
                self.hits.fetch_add(1, Ordering::SeqCst);
            }
        }
        let sys = ActorSystem::new();
        let release = Arc::new(AtomicBool::new(false));
        let hits = Arc::new(AtomicUsize::new(0));
        let (rel, h) = (release.clone(), hits.clone());
        let r = sys.spawn("deferring", 8, move || Deferring {
            release: rel.clone(),
            hits: h.clone(),
        });
        r.tell(1).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(hits.load(Ordering::SeqCst), 0, "deferred: nothing consumed");
        assert_eq!(r.mailbox_depth(), 1, "message still queued");
        release.store(true, Ordering::SeqCst);
        assert!(wait_until(|| hits.load(Ordering::SeqCst) == 1, Duration::from_secs(2)));
        sys.shutdown();
    }
}
