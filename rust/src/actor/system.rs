//! Actor trait, references, and the system that hosts actor threads.

use super::mailbox::{Mailbox, RecvError, SendError};
use crate::log_debug;
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// A typed actor. Implementations are plain structs; a fresh instance is
/// built by the spawn factory on every (re)start — the let-it-crash pattern
/// wipes in-memory state, and stateful actors recover via the state
/// management service (event sourcing), exactly as §2.2 prescribes.
pub trait Actor: Send + 'static {
    type Msg: Send + 'static;

    /// Called once per (re)start before the first message.
    fn pre_start(&mut self, _ctx: &mut Ctx<Self::Msg>) {}

    /// Handle one message. Panicking here marks the actor failed and
    /// triggers the system's failure hooks (supervision).
    fn receive(&mut self, msg: Self::Msg, ctx: &mut Ctx<Self::Msg>);

    /// Called on graceful stop (not on panic).
    fn post_stop(&mut self) {}
}

/// Execution context handed to the actor.
pub struct Ctx<M: Send + 'static> {
    /// This actor's own address.
    pub self_ref: ActorRef<M>,
    /// Restart count (0 on first incarnation).
    pub incarnation: u64,
    stop: bool,
}

impl<M: Send + 'static> Ctx<M> {
    /// Ask the runtime to stop this actor after the current message.
    pub fn stop(&mut self) {
        self.stop = true;
    }
}

/// Clonable, location-transparent actor address.
pub struct ActorRef<M> {
    pub path: Arc<String>,
    mailbox: Arc<Mailbox<M>>,
}

impl<M> Clone for ActorRef<M> {
    fn clone(&self) -> Self {
        ActorRef { path: self.path.clone(), mailbox: self.mailbox.clone() }
    }
}

impl<M: Send + 'static> ActorRef<M> {
    /// Fire-and-forget with backpressure (blocks while the mailbox is full).
    pub fn tell(&self, msg: M) -> Result<(), SendError> {
        self.mailbox.send(msg)
    }

    /// Non-blocking send.
    pub fn try_tell(&self, msg: M) -> Result<(), SendError> {
        self.mailbox.try_send(msg)
    }

    /// Non-blocking send that returns the message on failure (no clone
    /// needed by callers that want to redirect it).
    pub fn try_tell_back(&self, msg: M) -> Result<(), (SendError, M)> {
        self.mailbox.try_send_back(msg)
    }

    /// Mailbox depth — the signal the elastic-worker service scales on.
    pub fn mailbox_depth(&self) -> usize {
        self.mailbox.depth()
    }

    pub fn is_closed(&self) -> bool {
        self.mailbox.is_closed()
    }
}

/// Internal control handle for one hosted actor (type-erased).
trait Cell: Send + Sync {
    fn stop(&self);
    /// Crash semantics: discard queued messages, then stop.
    fn crash(&self);
    fn join(&self);
    fn is_running(&self) -> bool;
    fn mailbox_depth(&self) -> usize;
}

struct TypedCell<A: Actor> {
    path: Arc<String>,
    mailbox: Arc<Mailbox<A::Msg>>,
    factory: Box<dyn Fn() -> A + Send + Sync>,
    running: Arc<AtomicBool>,
    handle: Mutex<Option<JoinHandle<()>>>,
    incarnation: AtomicU64,
    hooks: FailureHooks,
}

type FailureHooks = Arc<RwLock<Vec<Box<dyn Fn(&str) + Send + Sync>>>>;

impl<A: Actor> TypedCell<A> {
    fn launch(self: &Arc<Self>) {
        let cell = self.clone();
        let incarnation = self.incarnation.fetch_add(1, Ordering::SeqCst);
        self.running.store(true, Ordering::SeqCst);
        self.mailbox.reopen();
        let handle = std::thread::Builder::new()
            .name(format!("actor:{}", self.path))
            .spawn(move || cell.run(incarnation))
            .expect("spawn actor thread");
        *self.handle.lock().unwrap() = Some(handle);
    }

    fn run(self: Arc<Self>, incarnation: u64) {
        let mut ctx = Ctx {
            self_ref: ActorRef { path: self.path.clone(), mailbox: self.mailbox.clone() },
            incarnation,
            stop: false,
        };
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut actor = (self.factory)();
            actor.pre_start(&mut ctx);
            loop {
                if ctx.stop {
                    actor.post_stop();
                    return;
                }
                match self.mailbox.recv_timeout(Duration::from_millis(20)) {
                    Ok(msg) => actor.receive(msg, &mut ctx),
                    Err(RecvError::Timeout) => continue,
                    Err(RecvError::Closed) => {
                        actor.post_stop();
                        return;
                    }
                }
            }
        }));
        self.running.store(false, Ordering::SeqCst);
        if result.is_err() {
            log_debug!("actor", "'{}' crashed (incarnation {incarnation})", self.path);
            // Notify supervision. The mailbox stays open so queued and
            // in-flight messages survive the restart.
            let hooks = self.hooks.read().unwrap();
            for hook in hooks.iter() {
                hook(&self.path);
            }
        }
    }
}

impl<A: Actor> Cell for TypedCell<A> {
    fn stop(&self) {
        self.mailbox.close();
    }

    fn crash(&self) {
        self.mailbox.close(); // stop accepting first…
        self.mailbox.purge(); // …then drop what was queued
    }

    fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    fn is_running(&self) -> bool {
        self.running.load(Ordering::SeqCst)
    }

    fn mailbox_depth(&self) -> usize {
        self.mailbox.depth()
    }
}

/// The actor system: spawns actors on dedicated threads, tracks them by
/// path, reports failures to registered hooks, and restarts failed actors
/// in place (same path, same mailbox).
pub struct ActorSystem {
    cells: RwLock<HashMap<String, Arc<dyn Cell>>>,
    restarters: RwLock<HashMap<String, Box<dyn Fn() + Send + Sync>>>,
    hooks: FailureHooks,
}

impl ActorSystem {
    pub fn new() -> Arc<Self> {
        Arc::new(ActorSystem {
            cells: RwLock::new(HashMap::new()),
            restarters: RwLock::new(HashMap::new()),
            hooks: Arc::new(RwLock::new(Vec::new())),
        })
    }

    /// Register a failure hook: called with the actor path whenever an
    /// actor panics. The supervision service registers itself here.
    pub fn on_failure(&self, hook: impl Fn(&str) + Send + Sync + 'static) {
        self.hooks.write().unwrap().push(Box::new(hook));
    }

    /// Spawn an actor. `factory` builds a fresh instance per incarnation.
    pub fn spawn<A: Actor>(
        self: &Arc<Self>,
        path: &str,
        capacity: usize,
        factory: impl Fn() -> A + Send + Sync + 'static,
    ) -> ActorRef<A::Msg> {
        let cell = Arc::new(TypedCell {
            path: Arc::new(path.to_string()),
            mailbox: Arc::new(Mailbox::new(capacity)),
            factory: Box::new(factory),
            running: Arc::new(AtomicBool::new(false)),
            handle: Mutex::new(None),
            incarnation: AtomicU64::new(0),
            hooks: self.hooks.clone(),
        });
        cell.launch();
        let r = ActorRef { path: cell.path.clone(), mailbox: cell.mailbox.clone() };
        {
            let c = cell.clone();
            self.restarters
                .write()
                .unwrap()
                .insert(path.to_string(), Box::new(move || c.launch()));
        }
        self.cells.write().unwrap().insert(path.to_string(), cell);
        r
    }

    /// Restart a failed (or stopped) actor in place: fresh instance, same
    /// path and mailbox. No-op if it is still running or unknown.
    pub fn restart(&self, path: &str) -> bool {
        let running = {
            let cells = self.cells.read().unwrap();
            match cells.get(path) {
                Some(c) => c.is_running(),
                None => return false,
            }
        };
        if running {
            return false;
        }
        if let Some(r) = self.restarters.read().unwrap().get(path) {
            r();
            true
        } else {
            false
        }
    }

    /// True if the actor exists and its thread is alive.
    pub fn is_running(&self, path: &str) -> bool {
        self.cells.read().unwrap().get(path).map(|c| c.is_running()).unwrap_or(false)
    }

    pub fn mailbox_depth(&self, path: &str) -> Option<usize> {
        self.cells.read().unwrap().get(path).map(|c| c.mailbox_depth())
    }

    /// Stop one actor (graceful: drains mailbox, runs `post_stop`).
    pub fn stop(&self, path: &str) {
        let cell = self.cells.read().unwrap().get(path).cloned();
        if let Some(c) = cell {
            c.stop();
            c.join();
        }
    }

    /// Remove an actor entirely (graceful stop + forget: queued messages
    /// are processed first). Its `ActorRef`s go dead.
    pub fn remove(&self, path: &str) {
        self.stop(path);
        self.cells.write().unwrap().remove(path);
        self.restarters.write().unwrap().remove(path);
    }

    /// Kill an actor as if its host died: queued messages are DROPPED,
    /// the in-flight message (if any) finishes (a thread cannot be safely
    /// torn mid-message), then the actor is forgotten.
    pub fn kill(&self, path: &str) {
        let cell = self.cells.read().unwrap().get(path).cloned();
        if let Some(c) = cell {
            c.crash();
            c.join();
        }
        self.cells.write().unwrap().remove(path);
        self.restarters.write().unwrap().remove(path);
    }

    /// All registered actor paths.
    pub fn paths(&self) -> Vec<String> {
        self.cells.read().unwrap().keys().cloned().collect()
    }

    /// Stop every actor (graceful), in no particular order.
    pub fn shutdown(&self) {
        let cells: Vec<Arc<dyn Cell>> = self.cells.read().unwrap().values().cloned().collect();
        for c in &cells {
            c.stop();
        }
        for c in &cells {
            c.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    struct Counter {
        hits: Arc<AtomicUsize>,
    }

    impl Actor for Counter {
        type Msg = u32;

        fn receive(&mut self, msg: u32, ctx: &mut Ctx<u32>) {
            if msg == u32::MAX {
                ctx.stop();
                return;
            }
            if msg == 666 {
                panic!("poison message");
            }
            self.hits.fetch_add(msg as usize, Ordering::SeqCst);
        }
    }

    fn wait_until(timeout: Duration, f: impl Fn() -> bool) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while std::time::Instant::now() < deadline {
            if f() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        f()
    }

    #[test]
    fn processes_messages() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("counter", 64, move || Counter { hits: h.clone() });
        for _ in 0..10 {
            r.tell(2).unwrap();
        }
        assert!(wait_until(Duration::from_secs(2), || hits.load(Ordering::SeqCst) == 20));
        sys.shutdown();
    }

    #[test]
    fn panic_is_contained_and_hooked() {
        let sys = ActorSystem::new();
        let failed = Arc::new(Mutex::new(Vec::<String>::new()));
        let f = failed.clone();
        sys.on_failure(move |path| f.lock().unwrap().push(path.to_string()));
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("fragile", 64, move || Counter { hits: h.clone() });
        r.tell(666).unwrap();
        assert!(wait_until(Duration::from_secs(2), || !sys.is_running("fragile")));
        assert_eq!(failed.lock().unwrap().as_slice(), &["fragile".to_string()]);
        sys.shutdown();
    }

    #[test]
    fn restart_keeps_address_and_mailbox() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("phoenix", 64, move || Counter { hits: h.clone() });
        r.tell(666).unwrap(); // crash
        assert!(wait_until(Duration::from_secs(2), || !sys.is_running("phoenix")));
        // Queue messages while down — the mailbox survives.
        r.tell(5).unwrap();
        r.tell(7).unwrap();
        assert!(sys.restart("phoenix"));
        assert!(wait_until(Duration::from_secs(2), || hits.load(Ordering::SeqCst) == 12));
        sys.shutdown();
    }

    #[test]
    fn restart_noop_when_running() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        sys.spawn("alive", 8, move || Counter { hits: h.clone() });
        assert!(wait_until(Duration::from_secs(1), || sys.is_running("alive")));
        assert!(!sys.restart("alive"));
        assert!(!sys.restart("nonexistent"));
        sys.shutdown();
    }

    #[test]
    fn ctx_stop_runs_post_stop_and_exits() {
        struct Stopper {
            stopped: Arc<AtomicUsize>,
        }
        impl Actor for Stopper {
            type Msg = ();
            fn receive(&mut self, _m: (), ctx: &mut Ctx<()>) {
                ctx.stop();
            }
            fn post_stop(&mut self) {
                self.stopped.fetch_add(1, Ordering::SeqCst);
            }
        }
        let sys = ActorSystem::new();
        let stopped = Arc::new(AtomicUsize::new(0));
        let s = stopped.clone();
        let r = sys.spawn("stopper", 8, move || Stopper { stopped: s.clone() });
        r.tell(()).unwrap();
        assert!(wait_until(Duration::from_secs(2), || stopped.load(Ordering::SeqCst) == 1));
        assert!(wait_until(Duration::from_secs(2), || !sys.is_running("stopper")));
        sys.shutdown();
    }

    #[test]
    fn kill_drops_queued_messages_remove_drains_them() {
        // Two identical slow actors with queued work: `remove` (graceful)
        // processes the queue, `kill` (crash) drops it.
        struct Slow {
            hits: Arc<AtomicUsize>,
        }
        impl Actor for Slow {
            type Msg = ();
            fn receive(&mut self, _m: (), _ctx: &mut Ctx<()>) {
                std::thread::sleep(Duration::from_millis(5));
                self.hits.fetch_add(1, Ordering::SeqCst);
            }
        }
        let sys = ActorSystem::new();
        let graceful_hits = Arc::new(AtomicUsize::new(0));
        let crashed_hits = Arc::new(AtomicUsize::new(0));
        let g = graceful_hits.clone();
        let c = crashed_hits.clone();
        let gr = sys.spawn("graceful", 64, move || Slow { hits: g.clone() });
        let cr = sys.spawn("crashed", 64, move || Slow { hits: c.clone() });
        for _ in 0..20 {
            gr.tell(()).unwrap();
            cr.tell(()).unwrap();
        }
        // Kill FIRST (before the graceful drain gives the other actor
        // 100ms to chew through its queue on a small host).
        sys.kill("crashed"); // drops the queue
        sys.remove("graceful"); // drains all 20
        assert_eq!(graceful_hits.load(Ordering::SeqCst), 20);
        assert!(
            crashed_hits.load(Ordering::SeqCst) < 20,
            "crash must drop queued work, processed {}",
            crashed_hits.load(Ordering::SeqCst)
        );
        sys.shutdown();
    }

    #[test]
    fn remove_kills_address() {
        let sys = ActorSystem::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        let r = sys.spawn("gone", 8, move || Counter { hits: h.clone() });
        sys.remove("gone");
        assert!(r.tell(1).is_err());
        assert!(sys.mailbox_depth("gone").is_none());
    }
}
