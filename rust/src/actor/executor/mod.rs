//! The actor executor: logical actors multiplexed over a fixed worker pool.
//!
//! Before this subsystem every actor owned a dedicated OS thread, so the
//! elastic worker service's scale-up signal translated into thread
//! creation and realistic scale capped at hundreds of actors. The
//! executor decouples the two: actors are **poll-driven state machines**
//! scheduled onto a small fixed pool of carrier threads, so 10k+ logical
//! actors run on `available_parallelism` OS threads (plus one timer
//! thread).
//!
//! The pieces:
//!
//! - [`Poller`] — one unit of schedulable work (an actor cell, a virtual
//!   consumer, a Liquid task). `poll(budget)` runs one *activation*:
//!   process up to `budget` messages, then report what should happen next
//!   via [`Poll`].
//! - [`Activation`] — the per-poller schedule handle. It carries one
//!   atomic schedule flag (a four-state machine: idle / scheduled /
//!   running / notified) so message arrival costs one CAS on the hot
//!   path — no condvar wait, no thread wakeup unless a worker is parked.
//!   [`Activation::notify`] is what mailboxes call on enqueue.
//! - [`Executor`] — the scheduling backend. [`ThreadedExecutor`] runs
//!   activations on a work-stealing worker pool against real time;
//!   [`crate::sim::SimExecutor`] runs them as discrete events on virtual
//!   time, single-threaded and deterministic, so chaos scenarios keep
//!   byte-identical fingerprints.
//! - [`TimerWheel`] (threaded backend only) — deadline re-activation for
//!   idle and backpressure waits: a poller returns [`Poll::After`] and is
//!   re-notified when the deadline expires (or sooner, if a message
//!   arrives first). This is what retired the `thread::sleep` pacing
//!   loops in the VML and processing layers.
//!
//! # Fairness
//!
//! Every activation is bounded by a message budget. A poller that still
//! has work after spending its budget returns [`Poll::Ready`] and goes to
//! the *back* of the shared injector queue, so a flooded actor cannot
//! starve its siblings beyond one budget's worth of messages.
//!
//! # Lifetime
//!
//! The executor holds only a [`Weak`] reference to each poller — the
//! owner (actor system, consumer group, job) keeps it alive; dropping the
//! owner's `Arc` quiesces the activation without explicit deregistration.

pub mod threaded;
pub mod timer;

pub use threaded::ThreadedExecutor;
pub use timer::TimerWheel;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Duration;

/// Default per-activation message budget (fairness quantum).
pub const DEFAULT_BUDGET: usize = 64;

/// What a poller wants after one activation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Poll {
    /// Nothing left to do: wait for an external [`Activation::notify`]
    /// (e.g. a message arriving in the mailbox).
    Idle,
    /// More work is queued (budget exhausted): re-activate as soon as a
    /// worker is free, behind already-scheduled peers.
    Ready,
    /// Idle poll or backpressure: re-activate after the given deadline on
    /// the executor's timer (or sooner if a notify arrives first).
    After(Duration),
}

/// A schedulable unit: one logical actor (or actor-like loop).
///
/// `poll` runs one activation. It is never invoked concurrently with
/// itself — the [`Activation`] state machine guarantees mutual exclusion —
/// so implementations may keep interior state behind an uncontended lock.
pub trait Poller: Send + Sync + 'static {
    /// Run one activation, processing at most `budget` messages.
    fn poll(&self, budget: usize) -> Poll;

    /// Stable identifier for logs and traces.
    fn path(&self) -> &str;
}

// Activation schedule states. The transitions:
//
//   notify:  IDLE -> SCHEDULED (enqueue) ; RUNNING -> NOTIFIED ; else no-op
//   run:     SCHEDULED -> RUNNING -> { SCHEDULED (Ready / notified-while-
//            running: re-enqueue), IDLE (Idle / After: timer re-notifies) }
const IDLE: u8 = 0;
const SCHEDULED: u8 = 1;
const RUNNING: u8 = 2;
const NOTIFIED: u8 = 3;

/// Scheduling backend an [`Activation`] pushes itself onto. Implemented
/// by the threaded core and the sim core.
pub(crate) trait ExecCore: Send + Sync {
    /// Queue an activation for execution (notify path: locality-friendly).
    fn enqueue(&self, act: Arc<Activation>);
    /// Queue a budget-exhausted activation behind all scheduled peers
    /// (fairness path).
    fn enqueue_yield(&self, act: Arc<Activation>);
    /// Re-notify an activation once `delay` has elapsed.
    fn enqueue_after(&self, delay: Duration, act: Arc<Activation>);
}

/// The per-poller schedule handle: one atomic flag + the executor hook.
///
/// Mailboxes (and anything else that makes a poller runnable) call
/// [`Activation::notify`]; the executor calls [`Activation::run`].
pub struct Activation {
    poller: Weak<dyn Poller>,
    path: String,
    state: AtomicU8,
    budget: usize,
    core: Weak<dyn ExecCore>,
    activations: AtomicU64,
}

impl Activation {
    pub(crate) fn new(
        poller: &Arc<dyn Poller>,
        budget: usize,
        core: Weak<dyn ExecCore>,
    ) -> Arc<Self> {
        Arc::new(Activation {
            path: poller.path().to_string(),
            poller: Arc::downgrade(poller),
            state: AtomicU8::new(IDLE),
            budget: budget.max(1),
            core,
            activations: AtomicU64::new(0),
        })
    }

    /// The registered poller's path.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// Activations executed so far (observability).
    pub fn activations(&self) -> u64 {
        self.activations.load(Ordering::Relaxed)
    }

    /// Make the poller runnable: one CAS on the hot path. Idempotent —
    /// notifying an already-scheduled or running activation coalesces
    /// into (at most) one extra run.
    pub fn notify(self: &Arc<Self>) {
        loop {
            match self.state.compare_exchange(
                IDLE,
                SCHEDULED,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    match self.core.upgrade() {
                        Some(core) => core.enqueue(self.clone()),
                        None => self.state.store(IDLE, Ordering::Release),
                    }
                    return;
                }
                Err(RUNNING) => {
                    if self
                        .state
                        .compare_exchange(RUNNING, NOTIFIED, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        return;
                    }
                    // State moved under us (run finished or a racing
                    // notify won); retry from the top.
                }
                Err(_) => return, // SCHEDULED or NOTIFIED: already pending
            }
        }
    }

    /// Execute one activation. Called only by executor backends, only on
    /// activations they popped from their queues (state == SCHEDULED).
    pub(crate) fn run(self: &Arc<Self>) {
        self.state.store(RUNNING, Ordering::Release);
        self.activations.fetch_add(1, Ordering::Relaxed);
        let verdict = match self.poller.upgrade() {
            // A panic that escapes a poller is contained here; pollers
            // hosting user code catch panics themselves to run their
            // failure hooks first.
            Some(p) => std::panic::catch_unwind(AssertUnwindSafe(|| p.poll(self.budget)))
                .unwrap_or(Poll::Idle),
            None => Poll::Idle, // owner dropped the poller: quiesce
        };
        match verdict {
            Poll::Ready => {
                self.state.store(SCHEDULED, Ordering::Release);
                match self.core.upgrade() {
                    Some(core) => core.enqueue_yield(self.clone()),
                    None => self.state.store(IDLE, Ordering::Release),
                }
            }
            Poll::Idle | Poll::After(_) => {
                match self.state.compare_exchange(
                    RUNNING,
                    IDLE,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        if let Poll::After(delay) = verdict {
                            if let Some(core) = self.core.upgrade() {
                                core.enqueue_after(delay, self.clone());
                            }
                        }
                    }
                    Err(_) => {
                        // NOTIFIED while running: go again immediately —
                        // new input trumps both Idle and the After delay.
                        self.state.store(SCHEDULED, Ordering::Release);
                        match self.core.upgrade() {
                            Some(core) => core.enqueue(self.clone()),
                            None => self.state.store(IDLE, Ordering::Release),
                        }
                    }
                }
            }
        }
    }
}

/// Shared wind-down plumbing for executor-hosted components (actor
/// cells, virtual consumers, Liquid tasks): the registered activation
/// plus the latch their stop paths wait on. One implementation instead
/// of three hand-rolled copies.
pub struct Registration {
    activation: Mutex<Option<Arc<Activation>>>,
    lock: Mutex<()>,
    cv: Condvar,
}

impl Registration {
    pub fn new() -> Self {
        Registration {
            activation: Mutex::new(None),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Install the activation handle (once, right after `register`).
    pub fn arm(&self, act: Arc<Activation>) {
        *self.activation.lock().unwrap() = Some(act);
    }

    /// Notify the registered activation (no-op before `arm`).
    pub fn notify(&self) {
        if let Some(act) = self.activation.lock().unwrap().as_ref() {
            act.notify();
        }
    }

    /// Wake every `join_while` waiter (call after flipping the
    /// component's down flag).
    pub fn wake_joiners(&self) {
        let _g = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    /// Wait (bounded) while `still_up` holds. Returns the final negated
    /// condition — true when the component wound down in time. A zero
    /// timeout returns immediately (cooperative executors like the sim
    /// backend drain only when their scheduler is pumped).
    pub fn join_while(&self, still_up: impl Fn() -> bool, timeout: Duration) -> bool {
        if timeout.is_zero() {
            return !still_up();
        }
        let deadline = std::time::Instant::now() + timeout;
        let mut g = self.lock.lock().unwrap();
        while still_up() {
            let now = std::time::Instant::now();
            if now >= deadline {
                return !still_up();
            }
            g = self.cv.wait_timeout(g, deadline - now).unwrap().0;
        }
        true
    }
}

impl Default for Registration {
    fn default() -> Self {
        Self::new()
    }
}

/// A scheduling backend for actor activations.
pub trait Executor: Send + Sync {
    /// Register a poller; returns its activation handle (initially idle —
    /// call [`Activation::notify`] to schedule the first activation).
    ///
    /// The executor keeps only a weak reference: the caller owns the
    /// poller, and dropping it quiesces the activation.
    fn register(&self, poller: Arc<dyn Poller>, budget: usize) -> Arc<Activation>;

    /// Carrier threads executing activations (1 for the sim executor).
    fn worker_count(&self) -> usize;

    /// True when activations make progress only while the caller pumps
    /// the executor (the sim backend). Stop paths must not block waiting
    /// for a cooperative executor's wind-down — nothing would drive it.
    fn is_cooperative(&self) -> bool {
        false
    }

    /// Stop executing. Threaded: joins workers and the timer thread;
    /// pending activations are dropped. Sim: no-op (the scheduler owns
    /// the event loop).
    fn shutdown(&self);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    /// Core that records enqueues without running anything.
    struct RecordingCore {
        enqueued: Mutex<Vec<Arc<Activation>>>,
        yields: AtomicUsize,
        timers: Mutex<Vec<Duration>>,
    }

    impl RecordingCore {
        fn new() -> Arc<Self> {
            Arc::new(RecordingCore {
                enqueued: Mutex::new(Vec::new()),
                yields: AtomicUsize::new(0),
                timers: Mutex::new(Vec::new()),
            })
        }
    }

    impl ExecCore for RecordingCore {
        fn enqueue(&self, act: Arc<Activation>) {
            self.enqueued.lock().unwrap().push(act);
        }
        fn enqueue_yield(&self, act: Arc<Activation>) {
            self.yields.fetch_add(1, Ordering::SeqCst);
            self.enqueued.lock().unwrap().push(act);
        }
        fn enqueue_after(&self, delay: Duration, _act: Arc<Activation>) {
            self.timers.lock().unwrap().push(delay);
        }
    }

    struct StubPoller {
        verdict: Mutex<Poll>,
        polls: AtomicUsize,
    }

    impl StubPoller {
        fn new(verdict: Poll) -> Arc<Self> {
            Arc::new(StubPoller { verdict: Mutex::new(verdict), polls: AtomicUsize::new(0) })
        }
    }

    impl Poller for StubPoller {
        fn poll(&self, _budget: usize) -> Poll {
            self.polls.fetch_add(1, Ordering::SeqCst);
            *self.verdict.lock().unwrap()
        }
        fn path(&self) -> &str {
            "stub"
        }
    }

    fn activation(
        poller: &Arc<StubPoller>,
        core: &Arc<RecordingCore>,
    ) -> Arc<Activation> {
        let p: Arc<dyn Poller> = poller.clone();
        let c: Weak<dyn ExecCore> = Arc::downgrade(core);
        Activation::new(&p, DEFAULT_BUDGET, c)
    }

    #[test]
    fn notify_enqueues_once() {
        let core = RecordingCore::new();
        let poller = StubPoller::new(Poll::Idle);
        let act = activation(&poller, &core);
        act.notify();
        act.notify(); // coalesced: already scheduled
        assert_eq!(core.enqueued.lock().unwrap().len(), 1);
    }

    #[test]
    fn run_idle_returns_to_idle_and_renotifies() {
        let core = RecordingCore::new();
        let poller = StubPoller::new(Poll::Idle);
        let act = activation(&poller, &core);
        act.notify();
        let queued = core.enqueued.lock().unwrap().pop().unwrap();
        queued.run();
        assert_eq!(poller.polls.load(Ordering::SeqCst), 1);
        assert_eq!(act.activations(), 1);
        // Back to idle: a new notify schedules again.
        act.notify();
        assert_eq!(core.enqueued.lock().unwrap().len(), 1);
    }

    #[test]
    fn ready_goes_through_yield_queue() {
        let core = RecordingCore::new();
        let poller = StubPoller::new(Poll::Ready);
        let act = activation(&poller, &core);
        act.notify();
        let queued = core.enqueued.lock().unwrap().pop().unwrap();
        queued.run();
        assert_eq!(core.yields.load(Ordering::SeqCst), 1, "Ready re-enqueues via yield");
        assert_eq!(core.enqueued.lock().unwrap().len(), 1);
    }

    #[test]
    fn after_schedules_timer() {
        let core = RecordingCore::new();
        let poller = StubPoller::new(Poll::After(Duration::from_millis(7)));
        let act = activation(&poller, &core);
        act.notify();
        let queued = core.enqueued.lock().unwrap().pop().unwrap();
        queued.run();
        assert_eq!(core.timers.lock().unwrap().as_slice(), &[Duration::from_millis(7)]);
        // Idle again: notify re-schedules immediately (message beats timer).
        act.notify();
        assert_eq!(core.enqueued.lock().unwrap().len(), 1);
    }

    #[test]
    fn poller_panic_is_contained() {
        struct Bomb;
        impl Poller for Bomb {
            fn poll(&self, _b: usize) -> Poll {
                panic!("boom");
            }
            fn path(&self) -> &str {
                "bomb"
            }
        }
        let core = RecordingCore::new();
        let p: Arc<dyn Poller> = Arc::new(Bomb);
        let c: Weak<dyn ExecCore> = Arc::downgrade(&core);
        let act = Activation::new(&p, 1, c);
        act.notify();
        let queued = core.enqueued.lock().unwrap().pop().unwrap();
        queued.run(); // must not unwind
        assert_eq!(act.activations(), 1);
    }

    #[test]
    fn dropped_poller_quiesces() {
        let core = RecordingCore::new();
        let poller = StubPoller::new(Poll::Ready);
        let act = activation(&poller, &core);
        drop(poller);
        act.notify();
        let queued = core.enqueued.lock().unwrap().pop().unwrap();
        queued.run(); // upgrade fails: treated as Idle, no re-enqueue
        assert!(core.enqueued.lock().unwrap().is_empty());
    }
}
