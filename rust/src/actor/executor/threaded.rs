//! The production executor: a fixed pool of worker threads with
//! per-worker run queues and work stealing.
//!
//! Scheduling policy:
//!
//! - a notify coming **from a worker thread** lands on that worker's own
//!   run queue (locality: an actor messaging another actor keeps the
//!   conversation on one core while the pool is busy);
//! - a notify from **outside the pool** (producers, the timer thread,
//!   tests) lands on the shared injector queue;
//! - a poller that exhausted its activation budget ([`Poll::Ready`])
//!   always re-queues onto the **back of the injector**, behind every
//!   already-scheduled peer — this is what makes the fairness budget a
//!   hard bound rather than a hint;
//! - an idle worker pops its local queue, then the injector, then
//!   **steals half** of a sibling's local queue; every eighth pop it
//!   checks the injector first so a self-refilling local queue cannot
//!   starve external work;
//! - with nothing to do, workers park on a condvar (with a short backstop
//!   timeout covering the enqueue/park race) — no spin, no sleep loop.
//!
//! [`Poll::Ready`]: super::Poll::Ready

use super::timer::TimerWheel;
use super::{Activation, ExecCore, Executor, Poller};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::Duration;

/// Covers the window between a worker's last queue scan and its condvar
/// wait; a wakeup lost to that race is repaired at the next backstop
/// tick. Purely defensive — the idle-counter/sleep-lock handshake is the
/// real wake path — so it can be generous: parked workers cost one
/// atomic load per tick.
const PARK_BACKSTOP: Duration = Duration::from_millis(20);

/// Check the injector first every N pops, so worker-local traffic cannot
/// starve externally-submitted work.
const INJECTOR_CHECK: u64 = 8;

static NEXT_CORE_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (core id, worker index) of the executor this thread belongs to.
    static WORKER: std::cell::Cell<Option<(u64, usize)>> =
        std::cell::Cell::new(None);
}

struct ThreadedCore {
    id: u64,
    injector: Mutex<VecDeque<Arc<Activation>>>,
    locals: Vec<Mutex<VecDeque<Arc<Activation>>>>,
    /// Activations sitting in the injector + local queues. Lets parked
    /// workers answer "any work?" with one atomic load instead of
    /// scanning every queue under the sleep lock (O(workers²) on an
    /// idle pool).
    queued: AtomicUsize,
    idle: AtomicUsize,
    sleep_lock: Mutex<()>,
    wake: Condvar,
    shutdown: AtomicBool,
    steals: AtomicU64,
    timer: TimerWheel,
}

impl ThreadedCore {
    fn has_work(&self) -> bool {
        self.queued.load(Ordering::SeqCst) > 0
    }

    fn wake_one(&self) {
        if self.idle.load(Ordering::SeqCst) > 0 {
            let _g = self.sleep_lock.lock().unwrap();
            self.wake.notify_one();
        }
    }

    fn wake_all(&self) {
        let _g = self.sleep_lock.lock().unwrap();
        self.wake.notify_all();
    }

    fn pop_injector(&self) -> Option<Arc<Activation>> {
        let popped = self.injector.lock().unwrap().pop_front();
        if popped.is_some() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
        }
        popped
    }

    fn pop_local(&self, idx: usize) -> Option<Arc<Activation>> {
        let popped = self.locals[idx].lock().unwrap().pop_front();
        if popped.is_some() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
        }
        popped
    }

    /// Steal half of a sibling's queue (victim lock released before
    /// touching our own queue, so two stealing workers can never hold
    /// each other's locks).
    fn steal_into(&self, idx: usize) -> Option<Arc<Activation>> {
        let n = self.locals.len();
        for k in 1..n {
            let victim = (idx + k) % n;
            let stolen: Vec<Arc<Activation>> = {
                let mut q = self.locals[victim].lock().unwrap();
                let take = q.len().div_ceil(2);
                q.drain(..take).collect()
            };
            if stolen.is_empty() {
                continue;
            }
            self.steals.fetch_add(1, Ordering::Relaxed);
            // One entry leaves the queues (returned below); the rest just
            // moves between locals, so the queued count drops by one.
            self.queued.fetch_sub(1, Ordering::SeqCst);
            let mut it = stolen.into_iter();
            let first = it.next();
            let rest: Vec<_> = it.collect();
            if !rest.is_empty() {
                self.locals[idx].lock().unwrap().extend(rest);
            }
            return first;
        }
        None
    }

    fn find_task(&self, idx: usize, tick: u64) -> Option<Arc<Activation>> {
        if tick % INJECTOR_CHECK == 0 {
            if let Some(a) = self.pop_injector() {
                return Some(a);
            }
        }
        if let Some(a) = self.pop_local(idx) {
            return Some(a);
        }
        if let Some(a) = self.pop_injector() {
            return Some(a);
        }
        self.steal_into(idx)
    }

    fn park(&self) {
        self.idle.fetch_add(1, Ordering::SeqCst);
        let g = self.sleep_lock.lock().unwrap();
        // Re-check under the sleep lock: an enqueuer that saw idle > 0
        // must take this lock to notify, so either we see its work here
        // or its notify reaches our wait.
        if !self.shutdown.load(Ordering::SeqCst) && !self.has_work() {
            let _ = self.wake.wait_timeout(g, PARK_BACKSTOP).unwrap();
        }
        self.idle.fetch_sub(1, Ordering::SeqCst);
    }

    fn worker_loop(self: Arc<Self>, idx: usize) {
        WORKER.with(|w| w.set(Some((self.id, idx))));
        let mut tick: u64 = 0;
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return;
            }
            tick = tick.wrapping_add(1);
            match self.find_task(idx, tick) {
                Some(act) => act.run(),
                None => self.park(),
            }
        }
    }
}

impl ExecCore for ThreadedCore {
    fn enqueue(&self, act: Arc<Activation>) {
        match WORKER.with(|w| w.get()) {
            Some((core_id, idx)) if core_id == self.id => {
                self.locals[idx].lock().unwrap().push_back(act);
            }
            _ => self.injector.lock().unwrap().push_back(act),
        }
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.wake_one();
    }

    fn enqueue_yield(&self, act: Arc<Activation>) {
        self.injector.lock().unwrap().push_back(act);
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.wake_one();
    }

    fn enqueue_after(&self, delay: Duration, act: Arc<Activation>) {
        self.timer.schedule(delay, act);
    }
}

/// Work-stealing executor on a fixed pool of OS threads.
pub struct ThreadedExecutor {
    core: Arc<ThreadedCore>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl ThreadedExecutor {
    /// Pool with `workers` carrier threads (clamped to ≥ 1) plus the
    /// timer thread.
    pub fn new(workers: usize) -> Arc<Self> {
        let workers = workers.max(1);
        let core = Arc::new(ThreadedCore {
            id: NEXT_CORE_ID.fetch_add(1, Ordering::Relaxed),
            injector: Mutex::new(VecDeque::new()),
            locals: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: AtomicUsize::new(0),
            idle: AtomicUsize::new(0),
            sleep_lock: Mutex::new(()),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            steals: AtomicU64::new(0),
            timer: TimerWheel::start(),
        });
        let handles = (0..workers)
            .map(|idx| {
                let c = core.clone();
                std::thread::Builder::new()
                    .name(format!("executor-worker-{idx}"))
                    .spawn(move || c.worker_loop(idx))
                    .expect("spawn executor worker")
            })
            .collect();
        Arc::new(ThreadedExecutor { core, workers: Mutex::new(handles) })
    }

    /// Pool sized to the host: one worker per available core.
    pub fn with_default_parallelism() -> Arc<Self> {
        Self::new(default_parallelism())
    }

    /// Successful steal operations so far (observability / tests).
    pub fn steal_count(&self) -> u64 {
        self.core.steals.load(Ordering::Relaxed)
    }

    /// Timer entries currently pending.
    pub fn timers_pending(&self) -> usize {
        self.core.timer.pending()
    }
}

/// One worker per available core (the executor default).
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl Executor for ThreadedExecutor {
    fn register(&self, poller: Arc<dyn Poller>, budget: usize) -> Arc<Activation> {
        let core: Weak<dyn ExecCore> = Arc::downgrade(&self.core);
        Activation::new(&poller, budget, core)
    }

    fn worker_count(&self) -> usize {
        self.core.locals.len()
    }

    fn shutdown(&self) {
        self.core.shutdown.store(true, Ordering::SeqCst);
        self.core.wake_all();
        let handles: Vec<JoinHandle<()>> = self.workers.lock().unwrap().drain(..).collect();
        // The last Arc to an executor can be dropped *from one of its own
        // workers* (an activation holding the final strong ref to a
        // component whose wiring owns the executor): never join the
        // current thread — it exits on the shutdown flag by itself.
        let me = std::thread::current().id();
        for h in handles {
            if h.thread().id() == me {
                continue;
            }
            let _ = h.join();
        }
        self.core.timer.shutdown();
    }
}

impl Drop for ThreadedExecutor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::executor::Poll;
    use crate::util::wait_until;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;
    use std::time::Instant;

    struct Counting {
        polls: AtomicUsize,
    }

    impl Counting {
        fn new() -> Arc<Self> {
            Arc::new(Counting { polls: AtomicUsize::new(0) })
        }
    }

    impl Poller for Counting {
        fn poll(&self, _budget: usize) -> Poll {
            self.polls.fetch_add(1, Ordering::SeqCst);
            Poll::Idle
        }
        fn path(&self) -> &str {
            "counting"
        }
    }

    #[test]
    fn runs_notified_pollers() {
        let exec = ThreadedExecutor::new(2);
        let p = Counting::new();
        let act = exec.register(p.clone(), 16);
        act.notify();
        assert!(wait_until(|| p.polls.load(Ordering::SeqCst) == 1, Duration::from_secs(2)));
        // Idle until notified again.
        act.notify();
        assert!(wait_until(|| p.polls.load(Ordering::SeqCst) == 2, Duration::from_secs(2)));
        exec.shutdown();
    }

    /// A poller draining a fixed amount of work `budget` units at a time,
    /// recording each activation into a shared event log.
    struct Draining {
        name: &'static str,
        remaining: AtomicUsize,
        events: Arc<Mutex<Vec<(&'static str, usize)>>>,
    }

    impl Poller for Draining {
        fn poll(&self, budget: usize) -> Poll {
            let left = self.remaining.load(Ordering::SeqCst);
            let take = left.min(budget);
            self.remaining.fetch_sub(take, Ordering::SeqCst);
            self.events.lock().unwrap().push((self.name, take));
            if left > take {
                Poll::Ready
            } else {
                Poll::Idle
            }
        }
        fn path(&self) -> &str {
            self.name
        }
    }

    /// Spins until released (pins one worker in place).
    struct Gate {
        open: Arc<AtomicBool>,
    }

    impl Poller for Gate {
        fn poll(&self, _budget: usize) -> Poll {
            let deadline = Instant::now() + Duration::from_secs(5);
            while !self.open.load(Ordering::SeqCst) && Instant::now() < deadline {
                std::hint::spin_loop();
            }
            Poll::Idle
        }
        fn path(&self) -> &str {
            "gate"
        }
    }

    #[test]
    fn flooding_poller_cannot_starve_siblings_beyond_budget() {
        let exec = ThreadedExecutor::new(1); // single worker: deterministic order
        let events = Arc::new(Mutex::new(Vec::new()));
        let open = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(Gate { open: open.clone() });
        let flooder = Arc::new(Draining {
            name: "flood",
            remaining: AtomicUsize::new(1000),
            events: events.clone(),
        });
        let sibling = Arc::new(Draining {
            name: "sib",
            remaining: AtomicUsize::new(1),
            events: events.clone(),
        });
        let g = exec.register(gate.clone(), 1);
        let f = exec.register(flooder.clone(), 64);
        let s = exec.register(sibling.clone(), 64);
        // Pin the only worker, then queue flooder before sibling.
        g.notify();
        std::thread::sleep(Duration::from_millis(20)); // gate is running
        f.notify();
        s.notify();
        open.store(true, Ordering::SeqCst);
        assert!(wait_until(
            || flooder.remaining.load(Ordering::SeqCst) == 0
                && sibling.remaining.load(Ordering::SeqCst) == 0,
            Duration::from_secs(5)
        ));
        let log = events.lock().unwrap().clone();
        let sib_at = log.iter().position(|(n, _)| *n == "sib").expect("sibling ran");
        let flooded_before: usize =
            log[..sib_at].iter().filter(|(n, _)| *n == "flood").map(|(_, k)| k).sum();
        assert!(
            flooded_before <= 64,
            "sibling waited behind {flooded_before} flooded messages (> one budget); log: {log:?}"
        );
        exec.shutdown();
    }

    /// Notifies its children from inside a worker (so they land on that
    /// worker's local queue), then keeps the worker busy.
    struct Spawner {
        children: Vec<Arc<Activation>>,
        hold: Duration,
    }

    impl Poller for Spawner {
        fn poll(&self, _budget: usize) -> Poll {
            for c in &self.children {
                c.notify();
            }
            let deadline = Instant::now() + self.hold;
            while Instant::now() < deadline {
                std::hint::spin_loop();
            }
            Poll::Idle
        }
        fn path(&self) -> &str {
            "spawner"
        }
    }

    #[test]
    fn skewed_local_queue_is_stolen_by_idle_worker() {
        let exec = ThreadedExecutor::new(2);
        let children: Vec<Arc<Counting>> = (0..8).map(|_| Counting::new()).collect();
        let child_acts: Vec<Arc<Activation>> =
            children.iter().map(|c| exec.register(c.clone(), 16)).collect();
        let spawner =
            Arc::new(Spawner { children: child_acts, hold: Duration::from_millis(200) });
        let sp = exec.register(spawner.clone(), 1);
        sp.notify();
        // While the spawner pins its worker, the other worker must steal
        // the children off the spawner's local queue.
        assert!(wait_until(
            || children.iter().all(|c| c.polls.load(Ordering::SeqCst) >= 1),
            Duration::from_secs(5)
        ));
        assert!(exec.steal_count() > 0, "children were drained without stealing");
        exec.shutdown();
    }

    /// First activation asks for a deadline; later ones idle.
    struct Backoff {
        polls: AtomicUsize,
        first_after: Duration,
    }

    impl Poller for Backoff {
        fn poll(&self, _budget: usize) -> Poll {
            if self.polls.fetch_add(1, Ordering::SeqCst) == 0 {
                Poll::After(self.first_after)
            } else {
                Poll::Idle
            }
        }
        fn path(&self) -> &str {
            "backoff"
        }
    }

    #[test]
    fn after_deadline_reactivates_via_timer() {
        let exec = ThreadedExecutor::new(1);
        let p = Arc::new(Backoff {
            polls: AtomicUsize::new(0),
            first_after: Duration::from_millis(5),
        });
        let act = exec.register(p.clone(), 1);
        let start = Instant::now();
        act.notify();
        assert!(wait_until(|| p.polls.load(Ordering::SeqCst) >= 2, Duration::from_secs(2)));
        assert!(
            start.elapsed() >= Duration::from_millis(5),
            "second activation fired before the deadline"
        );
        exec.shutdown();
    }

    #[test]
    fn ten_thousand_pollers_on_a_bounded_pool() {
        let exec = ThreadedExecutor::new(4);
        assert_eq!(exec.worker_count(), 4);
        let pollers: Vec<Arc<Counting>> = (0..10_000).map(|_| Counting::new()).collect();
        let acts: Vec<Arc<Activation>> =
            pollers.iter().map(|p| exec.register(p.clone(), 8)).collect();
        for a in &acts {
            a.notify();
        }
        assert!(wait_until(
            || pollers.iter().all(|p| p.polls.load(Ordering::SeqCst) >= 1),
            Duration::from_secs(10)
        ));
        exec.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let exec = ThreadedExecutor::new(2);
        exec.shutdown();
        exec.shutdown();
    }
}
