//! Deadline re-activation for the threaded executor.
//!
//! A poller that returns [`Poll::After`] parks until its deadline — the
//! timer then calls [`Activation::notify`], which goes through the normal
//! schedule flag (so a message arriving *before* the deadline wins, and a
//! deadline firing after the poller was already re-scheduled coalesces
//! into a no-op). One timer thread serves the whole executor: it fills
//! the classic timer-wheel role with a deadline-ordered heap, sleeping on
//! a condvar until the earliest due time (never polling).
//!
//! [`Poll::After`]: super::Poll::After

use super::Activation;
use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct TimerEntry {
    due: Instant,
    seq: u64,
    act: Arc<Activation>,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}

impl Eq for TimerEntry {}

impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for TimerEntry {
    /// Reversed so the std max-heap pops the *earliest* `(due, seq)`.
    fn cmp(&self, other: &Self) -> CmpOrdering {
        other.due.cmp(&self.due).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct TimerInner {
    heap: Mutex<BinaryHeap<TimerEntry>>,
    cv: Condvar,
    shutdown: AtomicBool,
    seq: AtomicU64,
}

/// The executor's timer: deadline-ordered re-notification.
pub struct TimerWheel {
    inner: Arc<TimerInner>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl TimerWheel {
    /// Start the timer thread.
    pub fn start() -> Self {
        let inner = Arc::new(TimerInner {
            heap: Mutex::new(BinaryHeap::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            seq: AtomicU64::new(0),
        });
        let i = inner.clone();
        let thread = std::thread::Builder::new()
            .name("executor-timer".to_string())
            .spawn(move || Self::drive(&i))
            .expect("spawn executor timer thread");
        TimerWheel { inner, thread: Mutex::new(Some(thread)) }
    }

    /// Notify `act` once `delay` has elapsed.
    pub fn schedule(&self, delay: Duration, act: Arc<Activation>) {
        let entry = TimerEntry {
            due: Instant::now() + delay,
            seq: self.inner.seq.fetch_add(1, Ordering::Relaxed),
            act,
        };
        let mut heap = self.inner.heap.lock().unwrap();
        let preempts = heap.peek().map(|head| entry.due < head.due).unwrap_or(true);
        heap.push(entry);
        drop(heap);
        if preempts {
            // New earliest deadline: wake the thread to re-arm its wait.
            self.inner.cv.notify_one();
        }
    }

    /// Entries currently pending (observability / tests).
    pub fn pending(&self) -> usize {
        self.inner.heap.lock().unwrap().len()
    }

    /// Stop the timer thread; pending entries are dropped.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        if let Some(h) = self.thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    fn drive(inner: &TimerInner) {
        let mut heap = inner.heap.lock().unwrap();
        loop {
            if inner.shutdown.load(Ordering::SeqCst) {
                heap.clear();
                return;
            }
            let now = Instant::now();
            let mut due = Vec::new();
            while heap.peek().map(|e| e.due <= now).unwrap_or(false) {
                due.push(heap.pop().expect("peeked entry"));
            }
            if !due.is_empty() {
                // Fire outside the lock: notify goes through the schedule
                // flag and may enqueue onto the executor.
                drop(heap);
                for e in due {
                    e.act.notify();
                }
                heap = inner.heap.lock().unwrap();
                continue;
            }
            heap = match heap.peek().map(|e| e.due) {
                Some(next) => {
                    let wait = next.saturating_duration_since(now);
                    inner.cv.wait_timeout(heap, wait).unwrap().0
                }
                None => inner.cv.wait(heap).unwrap(),
            };
        }
    }
}

impl Drop for TimerWheel {
    fn drop(&mut self) {
        self.shutdown();
    }
}
