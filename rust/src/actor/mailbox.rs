//! Bounded, depth-instrumented MPSC mailbox.
//!
//! Built on `Mutex<VecDeque>` + two condvars (not-empty / not-full). The
//! depth is mirrored into an atomic so the elastic-worker service and
//! routers can read queue lengths without touching the lock.
//!
//! Since the executor refactor the receiving side is **poll-driven**: the
//! hosting actor is activated by the executor and drains via
//! [`Mailbox::try_recv`], never blocking a worker thread. Message arrival
//! reaches the executor through the mailbox's *signal* — a callback
//! (wired to [`Activation::notify`]) invoked after every successful
//! enqueue and on close. The blocking [`Mailbox::recv_timeout`] remains
//! for non-actor consumers (tests, the ask pattern's reply side).
//!
//! [`Activation::notify`]: super::executor::Activation::notify

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::Duration;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendError {
    /// Mailbox closed (actor stopped): message went to dead letters.
    Closed,
    /// Mailbox full (only from `try_send`).
    Full,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Closed and drained.
    Closed,
    /// Timed out with no message.
    Timeout,
    /// Nothing queued right now (only from [`Mailbox::try_recv`]).
    Empty,
}

type Signal = Box<dyn Fn() + Send + Sync>;

pub struct Mailbox<M> {
    queue: Mutex<VecDeque<M>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    depth: AtomicUsize,
    closed: AtomicBool,
    /// Messages rejected because the mailbox was closed.
    dead: AtomicUsize,
    /// Enqueue/close callback (the owning actor's activation notify).
    /// Write-once so the send hot path reads it without a lock.
    signal: OnceLock<Signal>,
}

impl<M> Mailbox<M> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Mailbox {
            queue: Mutex::new(VecDeque::new()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            depth: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            dead: AtomicUsize::new(0),
            signal: OnceLock::new(),
        }
    }

    /// Install the enqueue signal (first installation wins — the actor
    /// system sets it exactly once, before any sender exists). The
    /// executor-hosted actor system points this at the actor's
    /// activation so message arrival schedules an activation.
    pub fn set_signal(&self, f: impl Fn() + Send + Sync + 'static) {
        let _ = self.signal.set(Box::new(f));
    }

    /// Fire the enqueue signal (called outside the queue lock).
    fn ping(&self) {
        if let Some(s) = self.signal.get() {
            s();
        }
    }

    /// Current queue depth (lock-free).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Relaxed)
    }

    /// Messages dropped because the mailbox was closed.
    pub fn dead_count(&self) -> usize {
        self.dead.load(Ordering::Relaxed)
    }

    /// Blocking send with backpressure; fails only if closed.
    pub fn send(&self, msg: M) -> Result<(), SendError> {
        self.send_back(msg).map_err(|(err, _msg)| err)
    }

    /// Blocking send that hands the message back on failure, so callers
    /// that spill to another target keep ownership without cloning.
    pub fn send_back(&self, msg: M) -> Result<(), (SendError, M)> {
        let mut q = self.queue.lock().unwrap();
        let mut msg = Some(msg);
        loop {
            if self.is_closed() {
                self.dead.fetch_add(1, Ordering::Relaxed);
                return Err((SendError::Closed, msg.take().expect("message present")));
            }
            if q.len() < self.capacity {
                q.push_back(msg.take().expect("message present"));
                self.depth.store(q.len(), Ordering::Relaxed);
                self.not_empty.notify_one();
                drop(q);
                self.ping();
                return Ok(());
            }
            q = self.not_full.wait_timeout(q, Duration::from_millis(50)).unwrap().0;
        }
    }

    /// Bounded-blocking send: waits on the not-full condvar up to
    /// `timeout`, then hands the message back with `Full` so the caller
    /// can re-sweep other targets (no head-of-line blocking on one
    /// mailbox).
    pub fn send_back_timeout(&self, msg: M, timeout: Duration) -> Result<(), (SendError, M)> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.queue.lock().unwrap();
        let mut msg = Some(msg);
        loop {
            if self.is_closed() {
                self.dead.fetch_add(1, Ordering::Relaxed);
                return Err((SendError::Closed, msg.take().expect("message present")));
            }
            if q.len() < self.capacity {
                q.push_back(msg.take().expect("message present"));
                self.depth.store(q.len(), Ordering::Relaxed);
                self.not_empty.notify_one();
                drop(q);
                self.ping();
                return Ok(());
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err((SendError::Full, msg.take().expect("message present")));
            }
            q = self.not_full.wait_timeout(q, deadline - now).unwrap().0;
        }
    }

    /// Non-blocking send.
    pub fn try_send(&self, msg: M) -> Result<(), SendError> {
        self.try_send_back(msg).map_err(|(err, _msg)| err)
    }

    /// Non-blocking send that hands the message back on failure, so
    /// callers (routers, batch publishers) can spill it to another target
    /// without cloning it up front.
    pub fn try_send_back(&self, msg: M) -> Result<(), (SendError, M)> {
        let mut q = self.queue.lock().unwrap();
        if self.is_closed() {
            self.dead.fetch_add(1, Ordering::Relaxed);
            return Err((SendError::Closed, msg));
        }
        if q.len() >= self.capacity {
            return Err((SendError::Full, msg));
        }
        q.push_back(msg);
        self.depth.store(q.len(), Ordering::Relaxed);
        self.not_empty.notify_one();
        drop(q);
        self.ping();
        Ok(())
    }

    /// Non-blocking receive: the executor's activation path. After close,
    /// drains remaining messages before reporting `Closed`.
    pub fn try_recv(&self) -> Result<M, RecvError> {
        let mut q = self.queue.lock().unwrap();
        if let Some(m) = q.pop_front() {
            self.depth.store(q.len(), Ordering::Relaxed);
            self.not_full.notify_one();
            return Ok(m);
        }
        if self.is_closed() {
            Err(RecvError::Closed)
        } else {
            Err(RecvError::Empty)
        }
    }

    /// Blocking receive with timeout. After close, drains remaining
    /// messages before reporting `Closed`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<M, RecvError> {
        let deadline = std::time::Instant::now() + timeout;
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(m) = q.pop_front() {
                self.depth.store(q.len(), Ordering::Relaxed);
                self.not_full.notify_one();
                return Ok(m);
            }
            if self.is_closed() {
                return Err(RecvError::Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, _res) = self.not_empty.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Drain up to `max` queued messages without blocking.
    pub fn drain(&self, max: usize) -> Vec<M> {
        let mut q = self.queue.lock().unwrap();
        let n = max.min(q.len());
        let out: Vec<M> = q.drain(..n).collect();
        self.depth.store(q.len(), Ordering::Relaxed);
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Discard everything queued (crash semantics). Returns the number of
    /// messages dropped.
    pub fn purge(&self) -> usize {
        let mut q = self.queue.lock().unwrap();
        let n = q.len();
        q.clear();
        self.depth.store(0, Ordering::Relaxed);
        self.not_full.notify_all();
        n
    }

    /// Close: senders fail fast, receivers drain then stop. Signals the
    /// activation so a poll-driven actor wakes to drain and exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Relaxed);
        self.not_empty.notify_all();
        self.not_full.notify_all();
        self.ping();
    }

    /// Reopen a closed mailbox (used when restarting an actor in place).
    pub fn reopen(&self) {
        self.closed.store(false, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as TestCounter;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let mb = Mailbox::new(10);
        for i in 0..5 {
            mb.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(mb.recv_timeout(Duration::from_millis(10)), Ok(i));
        }
        assert_eq!(mb.recv_timeout(Duration::from_millis(1)), Err(RecvError::Timeout));
    }

    #[test]
    fn try_send_full() {
        let mb = Mailbox::new(2);
        mb.try_send(1).unwrap();
        mb.try_send(2).unwrap();
        assert_eq!(mb.try_send(3), Err(SendError::Full));
        assert_eq!(mb.depth(), 2);
    }

    #[test]
    fn try_recv_reports_empty_then_closed() {
        let mb = Mailbox::new(4);
        assert_eq!(mb.try_recv(), Err(RecvError::Empty));
        mb.send("a").unwrap();
        assert_eq!(mb.try_recv(), Ok("a"));
        mb.close();
        assert_eq!(mb.try_recv(), Err(RecvError::Closed));
    }

    #[test]
    fn signal_fires_on_send_and_close() {
        let mb = Mailbox::new(4);
        let pings = Arc::new(TestCounter::new(0));
        let p = pings.clone();
        mb.set_signal(move || {
            p.fetch_add(1, Ordering::SeqCst);
        });
        mb.send(1).unwrap();
        mb.try_send(2).unwrap();
        assert_eq!(pings.load(Ordering::SeqCst), 2);
        mb.close();
        assert_eq!(pings.load(Ordering::SeqCst), 3, "close signals too");
        // Rejected sends do not signal.
        let _ = mb.send(3);
        assert_eq!(pings.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn try_send_back_returns_message_on_failure() {
        let mb = Mailbox::new(1);
        mb.try_send_back("a").unwrap();
        let (err, msg) = mb.try_send_back("b").unwrap_err();
        assert_eq!(err, SendError::Full);
        assert_eq!(msg, "b", "rejected message handed back");
        mb.close();
        let (err, msg) = mb.try_send_back("c").unwrap_err();
        assert_eq!(err, SendError::Closed);
        assert_eq!(msg, "c");
    }

    #[test]
    fn send_back_timeout_returns_full_after_deadline() {
        let mb = Mailbox::new(1);
        mb.send(1u32).unwrap();
        let start = std::time::Instant::now();
        let (err, msg) = mb.send_back_timeout(2u32, Duration::from_millis(20)).unwrap_err();
        assert_eq!(err, SendError::Full);
        assert_eq!(msg, 2);
        assert!(start.elapsed() >= Duration::from_millis(20));
        // Space frees up: the bounded send succeeds.
        assert_eq!(mb.try_recv(), Ok(1));
        mb.send_back_timeout(2u32, Duration::from_millis(20)).unwrap();
    }

    #[test]
    fn send_back_returns_message_when_closed() {
        let mb = Mailbox::new(1);
        mb.close();
        let (err, msg) = mb.send_back("x").unwrap_err();
        assert_eq!(err, SendError::Closed);
        assert_eq!(msg, "x");
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let mb = Mailbox::new(4);
        mb.send("a").unwrap();
        mb.close();
        assert_eq!(mb.send("b"), Err(SendError::Closed));
        assert_eq!(mb.dead_count(), 1);
        assert_eq!(mb.recv_timeout(Duration::from_millis(1)), Ok("a"));
        assert_eq!(mb.recv_timeout(Duration::from_millis(1)), Err(RecvError::Closed));
    }

    #[test]
    fn blocking_send_applies_backpressure() {
        let mb = Arc::new(Mailbox::new(1));
        mb.send(0u32).unwrap();
        let mb2 = mb.clone();
        let t = std::thread::spawn(move || {
            // Blocks until the consumer below makes room.
            mb2.send(1u32).unwrap();
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(mb.depth(), 1, "producer still blocked");
        assert_eq!(mb.recv_timeout(Duration::from_millis(100)), Ok(0));
        t.join().unwrap();
        assert_eq!(mb.recv_timeout(Duration::from_millis(100)), Ok(1));
    }

    #[test]
    fn cross_thread_handoff() {
        let mb = Arc::new(Mailbox::new(128));
        let mb2 = mb.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..1000u32 {
                mb2.send(i).unwrap();
            }
        });
        let mut got = vec![];
        while got.len() < 1000 {
            if let Ok(v) = mb.recv_timeout(Duration::from_millis(100)) {
                got.push(v);
            }
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn drain_bulk() {
        let mb = Mailbox::new(100);
        for i in 0..10 {
            mb.send(i).unwrap();
        }
        let d = mb.drain(4);
        assert_eq!(d, vec![0, 1, 2, 3]);
        assert_eq!(mb.depth(), 6);
        let rest = mb.drain(100);
        assert_eq!(rest.len(), 6);
    }

    #[test]
    fn purge_discards_queued() {
        let mb = Mailbox::new(8);
        mb.send(1).unwrap();
        mb.send(2).unwrap();
        assert_eq!(mb.purge(), 2);
        assert_eq!(mb.depth(), 0);
        assert_eq!(mb.recv_timeout(Duration::from_millis(1)), Err(RecvError::Timeout));
    }

    #[test]
    fn reopen_after_close() {
        let mb = Mailbox::new(2);
        mb.close();
        assert!(mb.send(1).is_err());
        mb.reopen();
        assert!(mb.send(1).is_ok());
    }
}
