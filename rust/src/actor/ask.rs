//! Request–reply over fire-and-forget messaging (the ask pattern).

use super::system::ActorRef;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A one-shot reply slot the responder fills in.
pub struct Reply<R> {
    inner: Arc<(Mutex<Option<R>>, Condvar)>,
}

impl<R> Clone for Reply<R> {
    fn clone(&self) -> Self {
        Reply { inner: self.inner.clone() }
    }
}

impl<R> Reply<R> {
    pub fn new() -> Self {
        Reply { inner: Arc::new((Mutex::new(None), Condvar::new())) }
    }

    /// Fulfil the reply (first write wins).
    pub fn send(&self, value: R) {
        let (slot, cv) = &*self.inner;
        let mut s = slot.lock().unwrap();
        if s.is_none() {
            *s = Some(value);
            cv.notify_all();
        }
    }

    /// Block until fulfilled or timeout.
    pub fn wait(&self, timeout: Duration) -> Option<R> {
        let (slot, cv) = &*self.inner;
        let deadline = std::time::Instant::now() + timeout;
        let mut s = slot.lock().unwrap();
        loop {
            if let Some(v) = s.take() {
                return Some(v);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _r) = cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }
}

impl<R> Default for Reply<R> {
    fn default() -> Self {
        Self::new()
    }
}

/// Send a request built from a fresh [`Reply`] and wait for the answer.
///
/// ```ignore
/// let depth = ask(&worker, |reply| WorkerMsg::GetDepth(reply), timeout);
/// ```
pub fn ask<M: Send + 'static, R>(
    target: &ActorRef<M>,
    make: impl FnOnce(Reply<R>) -> M,
    timeout: Duration,
) -> Option<R> {
    let reply = Reply::new();
    let msg = make(reply.clone());
    if target.tell(msg).is_err() {
        return None;
    }
    reply.wait(timeout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::system::{Actor, ActorSystem, Ctx};

    enum Msg {
        Add(u64),
        Get(Reply<u64>),
    }

    struct Summer {
        total: u64,
    }

    impl Actor for Summer {
        type Msg = Msg;
        fn receive(&mut self, msg: Msg, _ctx: &mut Ctx<Msg>) {
            match msg {
                Msg::Add(v) => self.total += v,
                Msg::Get(reply) => reply.send(self.total),
            }
        }
    }

    #[test]
    fn ask_round_trip() {
        let sys = ActorSystem::new();
        let r = sys.spawn("summer", 32, || Summer { total: 0 });
        r.tell(Msg::Add(3)).unwrap();
        r.tell(Msg::Add(4)).unwrap();
        let total = ask(&r, Msg::Get, Duration::from_secs(2));
        assert_eq!(total, Some(7));
        sys.shutdown();
    }

    #[test]
    fn wait_times_out() {
        let reply: Reply<u32> = Reply::new();
        assert_eq!(reply.wait(Duration::from_millis(20)), None);
    }

    #[test]
    fn first_write_wins() {
        let reply = Reply::new();
        reply.send(1);
        reply.send(2);
        assert_eq!(reply.wait(Duration::from_millis(10)), Some(1));
    }

    #[test]
    fn ask_dead_actor_is_none() {
        let sys = ActorSystem::new();
        let r = sys.spawn("tmp", 8, || Summer { total: 0 });
        sys.remove("tmp");
        assert_eq!(ask(&r, Msg::Get, Duration::from_millis(50)), None);
    }
}
