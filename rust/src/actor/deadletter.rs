//! Dead-letter accounting.
//!
//! Messages sent to closed mailboxes are counted per destination so
//! operators can see where flow is being dropped during failures. The
//! mailbox itself counts rejects; this registry aggregates across actors:
//! [`ActorSystem`] owns one instance and every [`ActorRef`] records its
//! closed-mailbox `tell`/`try_tell` rejects here. Bind a metrics gauge
//! with [`DeadLetters::bind_gauge`] to surface the running total in a
//! [`MetricsRegistry`].
//!
//! [`ActorSystem`]: super::system::ActorSystem
//! [`ActorRef`]: super::system::ActorRef
//! [`MetricsRegistry`]: crate::metrics::MetricsRegistry

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Aggregated dead-letter counts keyed by actor path.
pub struct DeadLetters {
    counts: Mutex<HashMap<String, u64>>,
    total: AtomicU64,
    /// Optional metrics gauge mirroring `total` (from
    /// [`MetricsRegistry::gauge`](crate::metrics::MetricsRegistry::gauge),
    /// whose handles are `'static`). Write-once so the reject hot path
    /// reads it lock-free.
    gauge: OnceLock<&'static AtomicI64>,
}

impl DeadLetters {
    pub fn new() -> Self {
        DeadLetters {
            counts: Mutex::new(HashMap::new()),
            total: AtomicU64::new(0),
            gauge: OnceLock::new(),
        }
    }

    /// Mirror the running total into a metrics gauge (e.g.
    /// `registry.gauge("actor.dead_letters")`). First binding wins;
    /// re-binding the same handle (the common idempotent case) is a
    /// no-op.
    pub fn bind_gauge(&self, gauge: &'static AtomicI64) {
        let _ = self.gauge.set(gauge);
        if let Some(g) = self.gauge.get() {
            g.fetch_max(self.total() as i64, Ordering::Relaxed);
        }
    }

    pub fn record(&self, path: &str) {
        *self.counts.lock().unwrap().entry(path.to_string()).or_insert(0) += 1;
        self.total.fetch_add(1, Ordering::Relaxed);
        // fetch_max of a freshly-loaded total: the gauge only moves
        // forward and converges to the true total even when records race
        // each other or the initial bind (an increment- or store-based
        // mirror could double-count or go backwards across those races).
        if let Some(g) = self.gauge.get() {
            g.fetch_max(self.total.load(Ordering::Relaxed) as i64, Ordering::Relaxed);
        }
    }

    pub fn count(&self, path: &str) -> u64 {
        self.counts.lock().unwrap().get(path).copied().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Snapshot sorted by count descending.
    pub fn top(&self, n: usize) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.counts.lock().unwrap().iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

impl Default for DeadLetters {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricsRegistry;

    #[test]
    fn counts_and_top() {
        let dl = DeadLetters::new();
        dl.record("a");
        dl.record("a");
        dl.record("b");
        assert_eq!(dl.count("a"), 2);
        assert_eq!(dl.count("missing"), 0);
        assert_eq!(dl.total(), 3);
        assert_eq!(dl.top(1), vec![("a".to_string(), 2)]);
    }

    #[test]
    fn bound_gauge_tracks_total() {
        let registry = MetricsRegistry::new();
        let dl = DeadLetters::new();
        dl.record("early"); // before binding
        dl.bind_gauge(registry.gauge("actor.dead_letters"));
        assert_eq!(registry.get_gauge("actor.dead_letters"), 1, "bind seeds current total");
        dl.record("late");
        dl.record("late");
        assert_eq!(registry.get_gauge("actor.dead_letters"), 3);
        assert_eq!(dl.total(), 3);
    }
}
