//! Dead-letter accounting.
//!
//! Messages sent to closed mailboxes are counted per destination so
//! operators can see where flow is being dropped during failures. (The
//! mailbox itself counts rejects; this registry aggregates across actors.)

use std::collections::HashMap;
use std::sync::Mutex;

/// Aggregated dead-letter counts keyed by actor path.
pub struct DeadLetters {
    counts: Mutex<HashMap<String, u64>>,
}

impl DeadLetters {
    pub fn new() -> Self {
        DeadLetters { counts: Mutex::new(HashMap::new()) }
    }

    pub fn record(&self, path: &str) {
        *self.counts.lock().unwrap().entry(path.to_string()).or_insert(0) += 1;
    }

    pub fn count(&self, path: &str) -> u64 {
        self.counts.lock().unwrap().get(path).copied().unwrap_or(0)
    }

    pub fn total(&self) -> u64 {
        self.counts.lock().unwrap().values().sum()
    }

    /// Snapshot sorted by count descending.
    pub fn top(&self, n: usize) -> Vec<(String, u64)> {
        let mut v: Vec<(String, u64)> =
            self.counts.lock().unwrap().iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }
}

impl Default for DeadLetters {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_top() {
        let dl = DeadLetters::new();
        dl.record("a");
        dl.record("a");
        dl.record("b");
        assert_eq!(dl.count("a"), 2);
        assert_eq!(dl.count("missing"), 0);
        assert_eq!(dl.total(), 3);
        assert_eq!(dl.top(1), vec![("a".to_string(), 2)]);
    }
}
