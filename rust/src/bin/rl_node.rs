//! `rl-node` — one Reactive Liquid node role on a real network.
//!
//! Roles:
//!
//! - `rl-node broker --listen 127.0.0.1:7411 [--data-dir DIR]
//!   [--fsync per-batch|interval:<ms>|off]` — serve a broker (plus
//!   gossip membership) over TCP and run until killed. With `--data-dir`
//!   the broker is **durable**: every partition writes through an
//!   on-disk segment log and committed offsets checkpoint, and on boot
//!   the broker recovers both (truncating torn tails, refusing cleanly
//!   on corruption it cannot repair);
//! - `rl-node worker --broker ADDR --messages N [--topic T]
//!   [--partitions P] [--batch B] [--node-id ID] [--group G]
//!   [--skip-publish]` — connect a [`RemoteBroker`], create the topic,
//!   publish `N` messages (unless `--skip-publish`), consume and commit
//!   them back in group `G`, print `processed=N`, exit.
//!
//! Two terminals make a real two-process pipeline:
//!
//! ```sh
//! rl-node broker --listen 127.0.0.1:7411 --data-dir /var/lib/rl
//! rl-node worker --broker 127.0.0.1:7411 --messages 500
//! ```
//!
//! # Cluster mode
//!
//! Give a broker `--node-id` and `--peers` and it becomes one seat of a
//! multi-broker cluster: it serves a [`ClusterView`]-aware broker (PR 7),
//! heartbeats its peers, and when the φ detector declares a peer dead it
//! rebalances partition ownership and gossips the new placement map.
//! Each partition is **replicated** to its top-`--replication` HRW nodes
//! (default 2): the primary forwards acked publishes to the followers,
//! the seat loop pulls this node's replica partitions to parity every
//! tick, and a failover promotes the surviving follower — a dead broker
//! loses no acked data. `--replication 1` restores the PR-7
//! primary-only behaviour. A worker pointed at `--seeds` routes through
//! a [`ClusterClient`] instead of a single [`RemoteBroker`]. Four terminals make a 3-broker
//! cluster (see the README quickstart):
//!
//! ```sh
//! rl-node broker --listen 127.0.0.1:7411 --node-id n1 --peers n2=127.0.0.1:7412,n3=127.0.0.1:7413
//! rl-node broker --listen 127.0.0.1:7412 --node-id n2 --peers n1=127.0.0.1:7411,n3=127.0.0.1:7413
//! rl-node broker --listen 127.0.0.1:7413 --node-id n3 --peers n1=127.0.0.1:7411,n2=127.0.0.1:7412
//! rl-node worker --seeds 127.0.0.1:7411,127.0.0.1:7412 --messages 500
//! ```
//!
//! The worker's wire layer rides broker restarts: connections redial,
//! publishes retry (re-creating the topic if the restarted broker lost
//! it), and consumers resubscribe. With `--data-dir`, a `kill -9`'d and
//! restarted broker serves every message it acknowledged before the
//! crash from disk (`tests/transport_tcp_e2e.rs` proves it with real OS
//! processes). Without it the broker is in-memory: a mid-run restart
//! loses its messages, and a worker that already published them reports
//! the shortfall and exits nonzero at its deadline rather than
//! pretending they were processed.

use reactive_liquid::cluster::membership::{ClusterView, Membership};
use reactive_liquid::cluster::{PlacementMap, DEFAULT_REPLICATION};
use reactive_liquid::config::cli::Args;
use reactive_liquid::messaging::client::SharedBrokerClient;
use reactive_liquid::messaging::{Broker, DiskStorage, FsyncPolicy, Message, StorageConfig};
use reactive_liquid::transport::{
    BrokerService, ClusterClient, Connection, Frame, Gossiper, GossipService, NodeService,
    RemoteBroker, RetryPolicy, TcpTransport, Transport, TransportError,
};
use reactive_liquid::util::clock::real_clock;
use std::io::Write;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env().unwrap_or_else(|e| {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    });
    let role = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let code = match role.as_str() {
        "broker" => cmd_broker(args),
        "worker" => cmd_worker(args),
        _ => {
            print!(
                "rl-node — run one Reactive Liquid node role\n\n\
                 usage: rl-node <broker|worker> [options]\n\n\
                 broker  --listen ADDR            serve the broker + membership over TCP\n\
                 \x20       [--data-dir DIR]         persist partitions + offsets, recover on boot\n\
                 \x20       [--fsync POLICY]         per-batch (default) | interval:<ms> | off\n\
                 \x20       [--node-id ID --peers id=addr,...]  join a multi-broker cluster\n\
                 \x20       [--advertise ADDR]       address peers/clients should use (default: --listen)\n\
                 \x20       [--replication K]        replicas per partition in cluster mode (default 2)\n\
                 worker  --broker ADDR | --seeds ADDR,ADDR,...\n\
                 \x20       --messages N [--topic T] [--partitions P]\n\
                 \x20       [--batch B] [--node-id ID] [--group G] [--skip-publish]\n"
            );
            0
        }
    };
    std::process::exit(code);
}

fn cmd_broker(mut args: Args) -> i32 {
    let listen = args.opt_str("listen").unwrap_or_else(|| "127.0.0.1:7411".to_string());
    let data_dir = args.opt_str("data-dir");
    let node_id = args.opt_str("node-id");
    let advertise = args.opt_str("advertise").unwrap_or_else(|| listen.clone());
    let peers_spec = args.opt_str("peers");
    let replication = match args.opt_or::<usize>("replication", DEFAULT_REPLICATION) {
        Ok(k) if k >= 1 => k,
        Ok(_) => {
            eprintln!("--replication needs >= 1");
            return 2;
        }
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let fsync = match args.opt_str("fsync") {
        None => FsyncPolicy::PerBatch,
        Some(s) => match FsyncPolicy::parse(&s) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }
    let broker = match &data_dir {
        None => Broker::new(),
        Some(dir) => {
            let cfg = StorageConfig { fsync, ..StorageConfig::default() };
            let storage = match DiskStorage::open(std::path::Path::new(dir), cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("open data dir {dir}: {e}");
                    return 1;
                }
            };
            // A recovery error means the on-disk state cannot be trusted
            // (damage before the log tail, corrupt manifest): refuse to
            // serve rather than start empty and silently lose data.
            match Broker::with_storage(storage) {
                Ok(b) => {
                    let topics = b.topic_names();
                    let messages: u64 =
                        topics.iter().filter_map(|t| b.topic(t)).map(|t| t.total_messages()).sum();
                    println!(
                        "rl-node broker recovered {} topic(s), {} message(s) from {dir} (fsync={})",
                        topics.len(),
                        messages,
                        fsync.label()
                    );
                    b
                }
                Err(e) => {
                    eprintln!("recover {dir}: {e}");
                    return 1;
                }
            }
        }
    };
    let membership = Membership::new(real_clock(), 8.0);
    let tcp = TcpTransport::default();

    // Clustered seat: a --peers roster makes this broker one node of a
    // placement-map cluster (see the module docs).
    if let Some(spec) = peers_spec {
        let node_id = node_id.unwrap_or_else(|| advertise.clone());
        let mut peers: Vec<(String, String)> = Vec::new();
        for part in spec.split(',').filter(|s| !s.is_empty()) {
            let Some((id, addr)) = part.split_once('=') else {
                eprintln!("--peers expects id=addr,id=addr,... (got '{part}')");
                return 2;
            };
            peers.push((id.to_string(), addr.to_string()));
        }
        let mut nodes = peers.clone();
        nodes.push((node_id.clone(), advertise.clone()));
        let view = ClusterView::new(&node_id, membership.clone(), PlacementMap::new(1, nodes));
        // Replication forwards run inside the publish handler, so their
        // transport fails fast — one dial, short timeout. A dead follower
        // costs one failed exchange before the down mark kicks in; the
        // catch-up tick re-proves it with the same cheap dial.
        let replication_tcp = TcpTransport {
            read_timeout: Duration::from_millis(500),
            connect_retries: 1,
            retry_backoff: Duration::from_millis(50),
        };
        let broker_service = BrokerService::with_replication(
            broker,
            view.clone(),
            Arc::new(replication_tcp),
            replication,
        );
        let service =
            NodeService::new(broker_service.clone(), GossipService::with_view(view.clone()));
        let handle = match tcp.serve(&listen, service) {
            Ok(h) => h,
            Err(e) => {
                eprintln!("bind {listen}: {e}");
                return 1;
            }
        };
        println!(
            "rl-node broker {node_id} listening on {} (cluster of {}, replication={replication})",
            handle.addr(),
            peers.len() + 1
        );
        let _ = std::io::stdout().flush();
        run_cluster_seat(&tcp, &node_id, peers, view, broker_service, membership);
    }

    let broker_service = BrokerService::new(broker);
    let service =
        NodeService::new(broker_service.clone(), GossipService::new(membership.clone()));
    let handle = match tcp.serve(&listen, service) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("bind {listen}: {e}");
            return 1;
        }
    };
    // The e2e harness waits for this line before starting workers.
    println!("rl-node broker listening on {}", handle.addr());
    let _ = std::io::stdout().flush();
    loop {
        std::thread::sleep(Duration::from_secs(5));
        // Sessions whose client died without a Leave (node loss) release
        // their group memberships here, so the group rebalances instead
        // of stalling on a dead member's partitions forever.
        let reaped = broker_service.reap_idle(Duration::from_secs(30));
        if reaped > 0 {
            eprintln!("reaped {reaped} idle consumer session(s)");
        }
        let suspects = membership.suspects();
        if !suspects.is_empty() {
            eprintln!("suspected members: {suspects:?}");
        }
    }
}

/// The clustered broker's supervision loop: heartbeat peers, watch the φ
/// detector, rebalance ownership away from the dead, gossip the map.
/// Never returns.
fn run_cluster_seat(
    tcp: &TcpTransport,
    node_id: &str,
    peers: Vec<(String, String)>,
    view: Arc<ClusterView>,
    broker_service: Arc<BrokerService>,
    membership: Arc<Membership>,
) -> ! {
    // Peers may come up in any order: connections dial lazily and a
    // failed dial is retried next tick, not fatal.
    struct Peer {
        id: String,
        addr: String,
        conn: Option<Arc<dyn Connection>>,
        gossiper: Option<Arc<Gossiper>>,
    }
    let mut peers: Vec<Peer> = peers
        .into_iter()
        .map(|(id, addr)| Peer { id, addr, conn: None, gossiper: None })
        .collect();
    let mut tick = 0u64;
    loop {
        std::thread::sleep(Duration::from_millis(500));
        tick += 1;
        let map = view.map();
        for peer in &mut peers {
            if peer.conn.is_none() {
                match tcp.connect(&peer.addr) {
                    Ok(c) => {
                        let g = Gossiper::new(c.clone(), node_id);
                        let _ = g.join(1);
                        peer.conn = Some(c);
                        peer.gossiper = Some(g);
                    }
                    Err(_) => continue, // retry next tick
                }
            }
            if let Some(g) = &peer.gossiper {
                let _ = g.heartbeat();
            }
            // Map anti-entropy: a restarted or partitioned-then-healed
            // peer adopts the highest epoch it hears.
            if tick % 4 == 0 {
                if let Some(c) = &peer.conn {
                    let cast = c.cast(&Frame::ClusterMapIs {
                        epoch: map.epoch(),
                        nodes: map.nodes().to_vec(),
                    });
                    if cast.is_err() {
                        // Dead link: drop it so the next tick redials.
                        peer.conn = None;
                        peer.gossiper = None;
                    }
                }
            }
        }
        // Failure-driven rebalance: when φ declares a mapped peer dead
        // (or a dead one heals), recompute ownership and gossip it.
        if let Some(next) = view.rebalance() {
            eprintln!(
                "cluster epoch {} -> {:?} own the partitions",
                next.epoch(),
                next.nodes().iter().map(|(id, _)| id.as_str()).collect::<Vec<_>>()
            );
            for peer in &peers {
                if let Some(c) = &peer.conn {
                    let _ = c.cast(&Frame::ClusterMapIs {
                        epoch: next.epoch(),
                        nodes: next.nodes().to_vec(),
                    });
                }
            }
        }
        // Replica catch-up: pull this node's follower partitions to
        // parity (a fresh restart or a healed partition heals here; the
        // empty parity pull clears our lagging mark on each primary).
        let caught_up = broker_service.catch_up_replicas(1024);
        if caught_up > 0 {
            eprintln!("replicas caught up {caught_up} message(s)");
        }
        if tick % 10 == 0 {
            let reaped = broker_service.reap_idle(Duration::from_secs(30));
            if reaped > 0 {
                eprintln!("reaped {reaped} idle session(s)");
            }
            let suspects = membership.suspects();
            if !suspects.is_empty() {
                eprintln!("suspected members: {suspects:?}");
            }
            // Replication health: which followers of partitions we own
            // are behind, and by how many messages.
            let lagging: Vec<(String, u64)> = broker_service
                .replica_lag()
                .into_iter()
                .filter(|(_, behind)| *behind > 0)
                .collect();
            if !lagging.is_empty() {
                eprintln!("lagging replicas: {lagging:?}");
            }
        }
    }
}

fn cmd_worker(mut args: Args) -> i32 {
    let broker_addr = args.opt_str("broker");
    let seeds = args.opt_str("seeds");
    let (addr, seeds) = match (broker_addr, seeds) {
        (Some(a), None) => (Some(a), None),
        (None, Some(s)) => (None, Some(s)),
        _ => {
            eprintln!("worker needs exactly one of --broker ADDR or --seeds ADDR,ADDR,...");
            return 2;
        }
    };
    // Numeric options: a value that fails to parse is an operator error,
    // not a silent fall-back to the default.
    let (total, partitions, batch) = match (
        args.opt_or::<u64>("messages", 200),
        args.opt_or::<usize>("partitions", 4),
        args.opt_or::<usize>("batch", 32),
    ) {
        (Ok(t), Ok(p), Ok(b)) => (t, p, b),
        (Err(e), ..) | (_, Err(e), _) | (.., Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let topic = args.opt_str("topic").unwrap_or_else(|| "wire-demo".to_string());
    let node_id = args.opt_str("node-id").unwrap_or_else(|| "worker".to_string());
    let group = args.opt_str("group").unwrap_or_else(|| "workers".to_string());
    let skip_publish = args.flag("skip-publish");
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }

    let tcp = TcpTransport::default();

    // Cluster worker: bootstrap a routed client from the seed list. The
    // gossip announcement goes to the first reachable seed — any clustered
    // broker spreads membership from there.
    if let Some(spec) = seeds {
        let seed_addrs: Vec<String> =
            spec.split(',').filter(|s| !s.is_empty()).map(|s| s.to_string()).collect();
        let client =
            match ClusterClient::connect(Arc::new(tcp.clone()), seed_addrs.clone(), RetryPolicy::default()) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("bootstrap from seeds {seed_addrs:?}: {e}");
                    return 1;
                }
            };
        let gossip_conn = seed_addrs.iter().find_map(|a| tcp.connect(a).ok());
        return with_heartbeats(gossip_conn, &node_id, || {
            run_pipeline(&client, &topic, &group, partitions, total, batch, skip_publish)
        });
    }

    let addr = addr.expect("checked above");
    let conn = match tcp.connect(&addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    let remote = RemoteBroker::new(conn.clone());
    with_heartbeats(Some(conn), &node_id, || {
        run_pipeline(&remote, &topic, &group, partitions, total, batch, skip_publish)
    })
}

/// Announce this worker over `conn` (when there is one) and heartbeat for
/// the duration of `body`.
fn with_heartbeats(
    conn: Option<Arc<dyn Connection>>,
    node_id: &str,
    body: impl FnOnce() -> i32,
) -> i32 {
    let Some(conn) = conn else { return body() };
    let gossiper = Gossiper::new(conn, node_id);
    let _ = gossiper.join(1);
    let stop_beats = Arc::new(AtomicBool::new(false));
    let beats = gossiper.start_heartbeats(Duration::from_millis(500), stop_beats.clone());
    let code = body();
    stop_beats.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = beats.join();
    code
}

/// Keep attempting `op` until it succeeds or `deadline` passes.
fn patient(deadline: Instant, what: &str, mut op: impl FnMut() -> bool) -> bool {
    loop {
        if op() {
            return true;
        }
        if Instant::now() >= deadline {
            eprintln!("gave up on {what}");
            return false;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
}

/// The fallible wire surface [`run_pipeline`] drives — satisfied by the
/// single-broker [`RemoteBroker`] and the cluster-routed [`ClusterClient`]
/// alike, so the worker body is identical either way.
trait WireClient {
    fn try_create_topic(&self, topic: &str, partitions: usize) -> Result<(), TransportError>;
    fn try_publish_batch(
        &self,
        topic: &str,
        msgs: Vec<Message>,
    ) -> Result<Vec<(usize, u64)>, TransportError>;
    fn shared(&self) -> SharedBrokerClient;
}

impl WireClient for Arc<RemoteBroker> {
    fn try_create_topic(&self, topic: &str, partitions: usize) -> Result<(), TransportError> {
        RemoteBroker::try_create_topic(self, topic, partitions)
    }
    fn try_publish_batch(
        &self,
        topic: &str,
        msgs: Vec<Message>,
    ) -> Result<Vec<(usize, u64)>, TransportError> {
        RemoteBroker::try_publish_batch(self, topic, msgs)
    }
    fn shared(&self) -> SharedBrokerClient {
        self.clone()
    }
}

impl WireClient for Arc<ClusterClient> {
    fn try_create_topic(&self, topic: &str, partitions: usize) -> Result<(), TransportError> {
        ClusterClient::try_create_topic(self, topic, partitions)
    }
    fn try_publish_batch(
        &self,
        topic: &str,
        msgs: Vec<Message>,
    ) -> Result<Vec<(usize, u64)>, TransportError> {
        ClusterClient::try_publish_batch(self, topic, msgs)
    }
    fn shared(&self) -> SharedBrokerClient {
        self.clone()
    }
}

/// Publish `total` messages (unless `skip_publish` — then the broker is
/// expected to already hold them, e.g. recovered from disk), then consume
/// + commit them back in `group`. Every wire operation is retried against
/// a deadline, so a broker restart mid-run stalls progress instead of
/// failing the worker.
fn run_pipeline(
    remote: &impl WireClient,
    topic: &str,
    group: &str,
    partitions: usize,
    total: u64,
    batch: usize,
    skip_publish: bool,
) -> i32 {
    let deadline = Instant::now() + Duration::from_secs(60);

    if !patient(deadline, "create-topic", || remote.try_create_topic(topic, partitions).is_ok()) {
        return 1;
    }

    // Publish with at-least-once retries: a batch whose ack was lost may
    // be retried and duplicated — the consume loop counts messages, which
    // only ever overshoots, never undershoots. An UnknownTopic rejection
    // means the broker restarted empty mid-run: re-create the topic and
    // keep going (what that broker lost is reported at the end).
    let mut published = if skip_publish { total } else { 0 };
    while published < total {
        let n = batch.min((total - published) as usize);
        let msgs: Vec<Message> = (0..n)
            .map(|i| Message::new(None, (published + i as u64).to_le_bytes().to_vec(), 0))
            .collect();
        let publish_once = || match remote.try_publish_batch(topic, msgs.clone()) {
            Ok(_) => true,
            Err(TransportError::Rejected { .. }) => {
                // Topic gone (restarted broker): recreate, then retry.
                let _ = remote.try_create_topic(topic, partitions);
                false
            }
            Err(_) => false,
        };
        if !patient(deadline, "publish", publish_once) {
            return 1;
        }
        published += n as u64;
    }

    // Consume + commit until everything published has been seen. The
    // client: SharedBrokerClient surface is exactly what the pipeline
    // layers use.
    let client: SharedBrokerClient = remote.shared();
    let consumer = client.subscribe(topic, group);
    let mut processed = 0u64;
    let consume_deadline = Instant::now() + Duration::from_secs(60);
    while processed < total && Instant::now() < consume_deadline {
        let polled = consumer.poll_batch(batch);
        if polled.is_empty() {
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        processed += polled.len() as u64;
        let _ = consumer.commit_batch(&polled);
    }
    consumer.close();
    println!("processed={processed}");
    let _ = std::io::stdout().flush();
    if processed >= total {
        0
    } else {
        eprintln!("only processed {processed}/{total} before the deadline");
        1
    }
}
