//! `rl-node` — one Reactive Liquid node role on a real network.
//!
//! Roles:
//!
//! - `rl-node broker --listen 127.0.0.1:7411 [--data-dir DIR]
//!   [--fsync per-batch|interval:<ms>|off]` — serve a broker (plus
//!   gossip membership) over TCP and run until killed. With `--data-dir`
//!   the broker is **durable**: every partition writes through an
//!   on-disk segment log and committed offsets checkpoint, and on boot
//!   the broker recovers both (truncating torn tails, refusing cleanly
//!   on corruption it cannot repair);
//! - `rl-node worker --broker ADDR --messages N [--topic T]
//!   [--partitions P] [--batch B] [--node-id ID] [--group G]
//!   [--skip-publish]` — connect a [`RemoteBroker`], create the topic,
//!   publish `N` messages (unless `--skip-publish`), consume and commit
//!   them back in group `G`, print `processed=N`, exit.
//!
//! Two terminals make a real two-process pipeline:
//!
//! ```sh
//! rl-node broker --listen 127.0.0.1:7411 --data-dir /var/lib/rl
//! rl-node worker --broker 127.0.0.1:7411 --messages 500
//! ```
//!
//! The worker's wire layer rides broker restarts: connections redial,
//! publishes retry (re-creating the topic if the restarted broker lost
//! it), and consumers resubscribe. With `--data-dir`, a `kill -9`'d and
//! restarted broker serves every message it acknowledged before the
//! crash from disk (`tests/transport_tcp_e2e.rs` proves it with real OS
//! processes). Without it the broker is in-memory: a mid-run restart
//! loses its messages, and a worker that already published them reports
//! the shortfall and exits nonzero at its deadline rather than
//! pretending they were processed.

use reactive_liquid::cluster::membership::Membership;
use reactive_liquid::config::cli::Args;
use reactive_liquid::messaging::client::SharedBrokerClient;
use reactive_liquid::messaging::{Broker, DiskStorage, FsyncPolicy, Message, StorageConfig};
use reactive_liquid::transport::{
    BrokerService, Gossiper, GossipService, NodeService, RemoteBroker, TcpTransport, Transport,
};
use reactive_liquid::util::clock::real_clock;
use std::io::Write;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let args = Args::from_env().unwrap_or_else(|e| {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    });
    let role = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let code = match role.as_str() {
        "broker" => cmd_broker(args),
        "worker" => cmd_worker(args),
        _ => {
            print!(
                "rl-node — run one Reactive Liquid node role\n\n\
                 usage: rl-node <broker|worker> [options]\n\n\
                 broker  --listen ADDR            serve the broker + membership over TCP\n\
                 \x20       [--data-dir DIR]         persist partitions + offsets, recover on boot\n\
                 \x20       [--fsync POLICY]         per-batch (default) | interval:<ms> | off\n\
                 worker  --broker ADDR --messages N [--topic T] [--partitions P]\n\
                 \x20       [--batch B] [--node-id ID] [--group G] [--skip-publish]\n"
            );
            0
        }
    };
    std::process::exit(code);
}

fn cmd_broker(mut args: Args) -> i32 {
    let listen = args.opt_str("listen").unwrap_or_else(|| "127.0.0.1:7411".to_string());
    let data_dir = args.opt_str("data-dir");
    let fsync = match args.opt_str("fsync") {
        None => FsyncPolicy::PerBatch,
        Some(s) => match FsyncPolicy::parse(&s) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }
    let broker = match &data_dir {
        None => Broker::new(),
        Some(dir) => {
            let cfg = StorageConfig { fsync, ..StorageConfig::default() };
            let storage = match DiskStorage::open(std::path::Path::new(dir), cfg) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("open data dir {dir}: {e}");
                    return 1;
                }
            };
            // A recovery error means the on-disk state cannot be trusted
            // (damage before the log tail, corrupt manifest): refuse to
            // serve rather than start empty and silently lose data.
            match Broker::with_storage(storage) {
                Ok(b) => {
                    let topics = b.topic_names();
                    let messages: u64 =
                        topics.iter().filter_map(|t| b.topic(t)).map(|t| t.total_messages()).sum();
                    println!(
                        "rl-node broker recovered {} topic(s), {} message(s) from {dir} (fsync={})",
                        topics.len(),
                        messages,
                        fsync.label()
                    );
                    b
                }
                Err(e) => {
                    eprintln!("recover {dir}: {e}");
                    return 1;
                }
            }
        }
    };
    let membership = Membership::new(real_clock(), 8.0);
    let broker_service = BrokerService::new(broker);
    let service =
        NodeService::new(broker_service.clone(), GossipService::new(membership.clone()));
    let tcp = TcpTransport::default();
    let handle = match tcp.serve(&listen, service) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("bind {listen}: {e}");
            return 1;
        }
    };
    // The e2e harness waits for this line before starting workers.
    println!("rl-node broker listening on {}", handle.addr());
    let _ = std::io::stdout().flush();
    loop {
        std::thread::sleep(Duration::from_secs(5));
        // Sessions whose client died without a Leave (node loss) release
        // their group memberships here, so the group rebalances instead
        // of stalling on a dead member's partitions forever.
        let reaped = broker_service.reap_idle(Duration::from_secs(30));
        if reaped > 0 {
            eprintln!("reaped {reaped} idle consumer session(s)");
        }
        let suspects = membership.suspects();
        if !suspects.is_empty() {
            eprintln!("suspected members: {suspects:?}");
        }
    }
}

fn cmd_worker(mut args: Args) -> i32 {
    let Some(addr) = args.opt_str("broker") else {
        eprintln!("worker needs --broker ADDR");
        return 2;
    };
    // Numeric options: a value that fails to parse is an operator error,
    // not a silent fall-back to the default.
    let (total, partitions, batch) = match (
        args.opt_or::<u64>("messages", 200),
        args.opt_or::<usize>("partitions", 4),
        args.opt_or::<usize>("batch", 32),
    ) {
        (Ok(t), Ok(p), Ok(b)) => (t, p, b),
        (Err(e), ..) | (_, Err(e), _) | (.., Err(e)) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let topic = args.opt_str("topic").unwrap_or_else(|| "wire-demo".to_string());
    let node_id = args.opt_str("node-id").unwrap_or_else(|| "worker".to_string());
    let group = args.opt_str("group").unwrap_or_else(|| "workers".to_string());
    let skip_publish = args.flag("skip-publish");
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        return 2;
    }

    let tcp = TcpTransport::default();
    let conn = match tcp.connect(&addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect {addr}: {e}");
            return 1;
        }
    };
    let remote = RemoteBroker::new(conn.clone());

    // Membership: announce ourselves and heartbeat until we exit.
    let gossiper = Gossiper::new(conn, &node_id);
    let _ = gossiper.join(1);
    let stop_beats = Arc::new(AtomicBool::new(false));
    let beats = gossiper.start_heartbeats(Duration::from_millis(500), stop_beats.clone());

    let code = run_pipeline(&remote, &topic, &group, partitions, total, batch, skip_publish);

    stop_beats.store(true, std::sync::atomic::Ordering::SeqCst);
    let _ = beats.join();
    code
}

/// Keep attempting `op` until it succeeds or `deadline` passes.
fn patient(deadline: Instant, what: &str, mut op: impl FnMut() -> bool) -> bool {
    loop {
        if op() {
            return true;
        }
        if Instant::now() >= deadline {
            eprintln!("gave up on {what}");
            return false;
        }
        std::thread::sleep(Duration::from_millis(200));
    }
}

/// Publish `total` messages (unless `skip_publish` — then the broker is
/// expected to already hold them, e.g. recovered from disk), then consume
/// + commit them back in `group`. Every wire operation is retried against
/// a deadline, so a broker restart mid-run stalls progress instead of
/// failing the worker.
fn run_pipeline(
    remote: &Arc<RemoteBroker>,
    topic: &str,
    group: &str,
    partitions: usize,
    total: u64,
    batch: usize,
    skip_publish: bool,
) -> i32 {
    let deadline = Instant::now() + Duration::from_secs(60);

    if !patient(deadline, "create-topic", || remote.try_create_topic(topic, partitions).is_ok()) {
        return 1;
    }

    // Publish with at-least-once retries: a batch whose ack was lost may
    // be retried and duplicated — the consume loop counts messages, which
    // only ever overshoots, never undershoots. An UnknownTopic rejection
    // means the broker restarted empty mid-run: re-create the topic and
    // keep going (what that broker lost is reported at the end).
    let mut published = if skip_publish { total } else { 0 };
    while published < total {
        let n = batch.min((total - published) as usize);
        let msgs: Vec<Message> = (0..n)
            .map(|i| Message::new(None, (published + i as u64).to_le_bytes().to_vec(), 0))
            .collect();
        let publish_once = || match remote.try_publish_batch(topic, msgs.clone()) {
            Ok(_) => true,
            Err(reactive_liquid::transport::TransportError::Rejected { .. }) => {
                // Topic gone (restarted broker): recreate, then retry.
                let _ = remote.try_create_topic(topic, partitions);
                false
            }
            Err(_) => false,
        };
        if !patient(deadline, "publish", publish_once) {
            return 1;
        }
        published += n as u64;
    }

    // Consume + commit until everything published has been seen. The
    // client: SharedBrokerClient surface is exactly what the pipeline
    // layers use.
    let client: SharedBrokerClient = remote.clone();
    let consumer = client.subscribe(topic, group);
    let mut processed = 0u64;
    let consume_deadline = Instant::now() + Duration::from_secs(60);
    while processed < total && Instant::now() < consume_deadline {
        let polled = consumer.poll_batch(batch);
        if polled.is_empty() {
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        processed += polled.len() as u64;
        let _ = consumer.commit_batch(&polled);
    }
    consumer.close();
    println!("processed={processed}");
    let _ = std::io::stdout().flush();
    if processed >= total {
        0
    } else {
        eprintln!("only processed {processed}/{total} before the deadline");
        1
    }
}
