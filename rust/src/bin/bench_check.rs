//! `bench_check` — validate emitted `BENCH_*.json` files and flag
//! throughput regressions against the committed baselines.
//!
//! ```sh
//! bench_check [--current DIR] [--baseline DIR] [--max-regression PCT]
//! ```
//!
//! - `--current` defaults to the benches' output dir (`$RL_BENCH_OUT` or
//!   `target/bench`); `--baseline` to `benches/baselines`.
//! - Every `BENCH_*.json` in the current dir must parse and carry a
//!   non-empty `points` array whose entries each have a `name` and at
//!   least one finite `throughput*` metric. `BENCH_durability.json`
//!   additionally must cover all three fsync policies — the issue's
//!   acceptance bar.
//! - A point whose throughput fell more than `--max-regression` percent
//!   (default 20) below the baseline fails the check — unless the
//!   baseline is marked `"provisional": true` (recorded on a machine
//!   whose numbers nobody should gate on), which downgrades the failure
//!   to a warning.
//!
//! Exit codes: 0 ok (warnings allowed), 1 validation failure or real
//! regression, 2 usage error.

use reactive_liquid::config::cli::Args;
use reactive_liquid::util::io::{bench_out_dir, Json};
use std::path::{Path, PathBuf};

fn main() {
    let mut args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let current = args.opt_str("current").map(PathBuf::from).unwrap_or_else(bench_out_dir);
    let baseline = args
        .opt_str("baseline")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("benches").join("baselines"));
    let max_regression = match args.opt_or::<f64>("max-regression", 20.0) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = args.finish() {
        eprintln!("{e}");
        std::process::exit(2);
    }

    let files = match bench_files(&current) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("FAIL: cannot list {}: {e}", current.display());
            std::process::exit(1);
        }
    };
    if files.is_empty() {
        eprintln!("FAIL: no BENCH_*.json files in {}", current.display());
        std::process::exit(1);
    }

    let mut failures = 0u32;
    for file in files {
        match check_file(&file, &baseline, max_regression) {
            Ok(notes) => {
                println!("ok: {}", file.display());
                for n in notes {
                    println!("  {n}");
                }
            }
            Err(why) => {
                eprintln!("FAIL: {}: {why}", file.display());
                failures += 1;
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} bench file(s) failed");
        std::process::exit(1);
    }
}

fn bench_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    out.sort();
    Ok(out)
}

/// A point's comparable metrics: every finite numeric `throughput*` key.
fn throughputs(point: &Json) -> Vec<(String, f64)> {
    match point {
        Json::Obj(m) => m
            .iter()
            .filter(|(k, _)| k.starts_with("throughput"))
            .filter_map(|(k, v)| v.as_f64().filter(|n| n.is_finite()).map(|n| (k.clone(), n)))
            .collect(),
        _ => Vec::new(),
    }
}

/// Validate one result file and diff it against its baseline. Returns
/// human-readable notes on success, the failure reason otherwise.
fn check_file(file: &Path, baseline_dir: &Path, max_regression: f64) -> Result<Vec<String>, String> {
    let text = std::fs::read_to_string(file).map_err(|e| format!("unreadable: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("invalid JSON: {e}"))?;
    let bench = doc
        .get("bench")
        .and_then(Json::as_str)
        .ok_or("missing string field 'bench'")?
        .to_string();
    let points = doc
        .get("points")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'points'")?;
    if points.is_empty() {
        return Err("empty 'points' array".into());
    }
    let mut names = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let name = p
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("point {i}: missing 'name'"))?;
        if throughputs(p).is_empty() {
            return Err(format!("point '{name}': no finite throughput metric"));
        }
        names.push(name.to_string());
    }
    if bench == "durability" {
        // The acceptance bar: one throughput point per fsync policy.
        for required in ["disk-per-batch", "disk-interval", "disk-off"] {
            if !names.iter().any(|n| n.starts_with(required)) {
                return Err(format!("durability bench missing the '{required}*' policy point"));
            }
        }
    }

    let mut notes = Vec::new();
    let base_path = baseline_dir.join(file.file_name().unwrap());
    let base_text = match std::fs::read_to_string(&base_path) {
        Ok(t) => t,
        Err(_) => {
            notes.push(format!("no baseline at {} — nothing to compare", base_path.display()));
            return Ok(notes);
        }
    };
    let base = Json::parse(&base_text)
        .map_err(|e| format!("baseline {} invalid: {e}", base_path.display()))?;
    let provisional = base.get("provisional").and_then(Json::as_bool).unwrap_or(false);
    let base_points = base.get("points").and_then(Json::as_arr).unwrap_or(&[]);

    let mut regressions = Vec::new();
    for p in points {
        let name = p.get("name").and_then(Json::as_str).unwrap_or_default();
        let Some(bp) = base_points
            .iter()
            .find(|bp| bp.get("name").and_then(Json::as_str) == Some(name))
        else {
            notes.push(format!("point '{name}' has no baseline entry"));
            continue;
        };
        let base_metrics = throughputs(bp);
        for (key, cur) in throughputs(p) {
            let Some((_, base_v)) = base_metrics.iter().find(|(k, _)| *k == key) else {
                continue;
            };
            if *base_v <= 0.0 {
                continue;
            }
            let delta_pct = (cur - base_v) / base_v * 100.0;
            if delta_pct < -max_regression {
                regressions.push(format!(
                    "'{name}' {key}: {cur:.0} vs baseline {base_v:.0} ({delta_pct:+.1}%)"
                ));
            } else {
                notes.push(format!("'{name}' {key}: {delta_pct:+.1}% vs baseline"));
            }
        }
    }
    if regressions.is_empty() {
        return Ok(notes);
    }
    if provisional {
        for r in &regressions {
            notes.push(format!("WARN (provisional baseline): regression {r}"));
        }
        Ok(notes)
    } else {
        Err(format!(">{max_regression}% regression: {}", regressions.join("; ")))
    }
}
