//! Minimal, API-compatible subset of the `anyhow` crate for the offline
//! build environment (no registry access — see the workspace README).
//!
//! Provides exactly what `reactive_liquid` uses: [`Error`], the
//! [`Result`] alias, the [`anyhow!`] macro, and the [`Context`] extension
//! trait on `Result` and `Option`. Errors are plain message strings;
//! context is prepended `"{context}: {cause}"`, matching how the real
//! crate renders its chains with `{:#}`.

use std::fmt;

/// A string-backed error value.
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// `Result` defaulted to [`Error`], as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a message, a displayable value, or format
/// arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Attach context to failures, turning them into [`Error`]s.
pub trait Context<T> {
    /// Wrap the failure with `context`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the context lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Debug> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error(format!("{context}: {e:?}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e:?}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("boom"))
    }

    #[test]
    fn macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
        assert_eq!(anyhow!("{} {}", 1, "two").to_string(), "1 two");
        assert!(fails().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io"));
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file: "));

        let o: Option<u32> = None;
        let e = o.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_lazy() {
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("k={}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "k=3");
    }
}
