//! Stub of the `xla-rs` PJRT bindings for the offline build environment.
//!
//! The real crate links `libxla_extension`; this container has neither the
//! shared library nor registry access, so this in-tree stand-in exposes the
//! same type/method surface and fails fast at the only entry point —
//! [`PjRtClient::cpu`] — with a recognizable error. Every caller in
//! `reactive_liquid` already handles that error by falling back to the
//! scalar CPU path (see `tcmm::backend::XlaBackend`), so the stack runs
//! end-to-end without PJRT; swapping the real crate back in via
//! `Cargo.toml` re-enables the AOT kernels with no source changes.

use std::fmt;
use std::marker::PhantomData;

/// Stub error: every operation reports the runtime as unavailable.
pub struct Error(String);

impl Error {
    fn unavailable(op: &str) -> Self {
        Error(format!("{op}: xla stub (PJRT unavailable in this build)"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtype of a literal/buffer (subset of the real enum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    U64,
    F32,
    F64,
}

/// Types extractable from a [`Literal`] with [`Literal::to_vec`].
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side tensor value.
#[derive(Clone, Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn element_type(&self) -> Result<ElementType> {
        Err(Error::unavailable("Literal::element_type"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer returned by [`PjRtLoadedExecutable::execute`].
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// The generic parameter mirrors the real API (`execute::<Literal>`);
    /// the stub ignores it.
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = PhantomData::<fn() -> L>;
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. The stub cannot create one — [`PjRtClient::cpu`] is the
/// single failure point the rest of the stack gates on.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err}");
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn literal_ops_fail_gracefully() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.element_type().is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
