"""L2 — the TCMM compute graph, composed from the L1 Pallas kernels.

This is the layer `aot.py` lowers to HLO text. Two entry points:

- ``tcmm_assign``: batched nearest-micro-cluster assignment (the
  micro-clustering job's hot loop);
- ``macro_kmeans_step``: one weighted Lloyd iteration over micro-cluster
  centers (the macro-clustering job's hot loop).

Both take *statically padded* shapes — the rust caller pads points/centers
to the artifact's (B, K) and masks with ``valid``/zero weights.
"""

import jax.numpy as jnp

from .kernels import kmeans, nearest


def tcmm_assign(points, centers, valid):
    """(idx s32[B], dist f32[B]) — nearest valid center per point.

    Wraps the Pallas kernel so additional graph-level logic (dtype
    hygiene, future decay terms) lives above the kernel, not in it.
    """
    points = points.astype(jnp.float32)
    centers = centers.astype(jnp.float32)
    valid = valid.astype(jnp.float32)
    return nearest.nearest(points, centers, valid)


def macro_kmeans_step(points, weights, centroids):
    """(new_centroids f32[C, D], counts f32[C]) — one weighted Lloyd step."""
    points = points.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    centroids = centroids.astype(jnp.float32)
    return kmeans.kmeans_step(points, weights, centroids)
