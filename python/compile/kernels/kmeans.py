"""Pallas kernel: one weighted Lloyd (k-means) step over micro-cluster
centers — TCMM's macro-clustering inner loop.

Grid sweeps point blocks; each step assigns its block to the nearest
centroid (MXU-shaped distance tile, like `nearest.py`) and accumulates
weighted one-hot partial sums into the output refs. Centroid count C is
small (≤ a few dozen macro-clusters), so centroids and the accumulators sit
in VMEM for the whole sweep.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Points processed per grid step.
P_BLK = 128


def _kmeans_kernel(points_ref, weights_ref, centroids_ref, sums_ref, counts_ref):
    pb = pl.program_id(0)

    points = points_ref[...]  # [P_BLK, D]
    weights = weights_ref[...]  # [P_BLK]
    centroids = centroids_ref[...]  # [C, D]
    c = centroids.shape[0]

    p2 = jnp.sum(points * points, axis=1, keepdims=True)
    c2 = jnp.sum(centroids * centroids, axis=1)[None, :]
    cross = jnp.dot(points, centroids.T, preferred_element_type=jnp.float32)
    d2 = p2 - 2.0 * cross + c2  # [P_BLK, C]
    assign = jnp.argmin(d2, axis=1)  # [P_BLK]

    onehot = (assign[:, None] == jnp.arange(c)[None, :]).astype(jnp.float32)
    w = weights[:, None] * onehot  # [P_BLK, C]
    # MXU-shaped accumulation: [C, P_BLK] @ [P_BLK, D].
    part_sums = jnp.dot(w.T, points, preferred_element_type=jnp.float32)  # [C, D]
    part_counts = jnp.sum(w, axis=0)  # [C]

    @pl.when(pb == 0)
    def _init():
        sums_ref[...] = part_sums
        counts_ref[...] = part_counts

    @pl.when(pb != 0)
    def _acc():
        sums_ref[...] += part_sums
        counts_ref[...] += part_counts


@jax.jit
def kmeans_step(points, weights, centroids):
    """One weighted Lloyd step.

    points f32[K, D] (K % P_BLK == 0; padding rows must carry weight 0),
    weights f32[K], centroids f32[C, D]. Returns (new_centroids f32[C, D],
    counts f32[C]); empty centroids keep their previous position, matching
    `ref.kmeans_step_ref`.
    """
    k, d = points.shape
    c, _ = centroids.shape
    assert k % P_BLK == 0, f"K={k} not a multiple of {P_BLK}"

    # Mean-center (translation-invariant) to dodge f32 cancellation in the
    # MXU distance expansion — see nearest.py.
    shift = jnp.mean(centroids, axis=0, keepdims=True)
    points = points - shift
    centroids = centroids - shift

    sums, counts = pl.pallas_call(
        _kmeans_kernel,
        grid=(k // P_BLK,),
        in_specs=[
            pl.BlockSpec((P_BLK, d), lambda pb: (pb, 0)),
            pl.BlockSpec((P_BLK,), lambda pb: (pb,)),
            pl.BlockSpec((c, d), lambda pb: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((c, d), lambda pb: (0, 0)),
            pl.BlockSpec((c,), lambda pb: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, d), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=True,
    )(points, weights, centroids)

    # Divide by the true counts (guarded against 0/0, not clamped — tiny
    # weight sums must still normalize exactly).
    safe = jnp.where(counts > 0, counts, 1.0)
    new_centroids = jnp.where(counts[:, None] > 0, sums / safe[:, None], centroids)
    return new_centroids + shift, counts
