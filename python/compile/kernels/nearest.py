"""Pallas kernel: nearest-valid-center search (TCMM's hot-spot).

Layout strategy (see DESIGN.md §Hardware-Adaptation): the point block
stays resident in VMEM while the kernel sweeps center blocks along the
grid; the distance tile is a (B_BLK × K_BLK) matmul-shaped computation that
targets the MXU via the `p·cᵀ` cross term, and the running (min, argmin)
pair lives in the output refs — the classic streaming-argmin pattern that
avoids materializing the full B×K distance matrix in HBM.

Executed with `interpret=True` everywhere in this repo (CPU PJRT cannot
run Mosaic custom-calls); on a real TPU the same BlockSpecs express the
HBM↔VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INVALID_PENALTY

#: Block sizes. B_BLK×K_BLK f32 distance tile = 128×128×4 B = 64 KiB —
#: comfortably inside a TPU core's ~16 MiB VMEM together with the point
#: and center blocks (128×2 f32 each) and double-buffering headroom.
B_BLK = 128
K_BLK = 128


def _nearest_kernel(points_ref, centers_ref, valid_ref, idx_ref, dist_ref):
    """Grid = (K // K_BLK,). One step: fold one center block into the
    running argmin held in the output refs."""
    kb = pl.program_id(0)

    points = points_ref[...]  # [B_BLK, D] — same block every step
    centers = centers_ref[...]  # [K_BLK, D] — this step's block
    valid = valid_ref[...]  # [K_BLK]

    # Squared distances for the tile, MXU-shaped: p·cᵀ is the matmul.
    p2 = jnp.sum(points * points, axis=1, keepdims=True)  # [B_BLK, 1]
    c2 = jnp.sum(centers * centers, axis=1)[None, :]  # [1, K_BLK]
    cross = jnp.dot(points, centers.T, preferred_element_type=jnp.float32)
    d2 = p2 - 2.0 * cross + c2
    d2 = d2 + (1.0 - valid)[None, :] * INVALID_PENALTY

    local_idx = jnp.argmin(d2, axis=1).astype(jnp.int32)  # [B_BLK]
    local_min = jnp.min(d2, axis=1)  # [B_BLK]
    global_idx = local_idx + kb * K_BLK

    @pl.when(kb == 0)
    def _init():
        idx_ref[...] = global_idx
        dist_ref[...] = local_min

    @pl.when(kb != 0)
    def _fold():
        better = local_min < dist_ref[...]
        idx_ref[...] = jnp.where(better, global_idx, idx_ref[...])
        dist_ref[...] = jnp.where(better, local_min, dist_ref[...])


@functools.partial(jax.jit, static_argnames=())
def nearest(points, centers, valid):
    """Nearest valid center per point via the Pallas kernel.

    Shapes must be multiples of the block sizes (the AOT wrapper pads):
    points f32[B, D], centers f32[K, D], valid f32[K] with B % B_BLK == 0
    and K % K_BLK == 0. Returns (idx s32[B], dist f32[B]) with `dist` the
    Euclidean (not squared) distance, matching `ref.nearest_ref`.
    """
    b, d = points.shape
    k, _ = centers.shape
    assert b % B_BLK == 0, f"B={b} not a multiple of {B_BLK}"
    assert k % K_BLK == 0, f"K={k} not a multiple of {K_BLK}"
    n_kb = k // K_BLK

    # Mean-center both operands (translation-invariant): GPS coordinates
    # carry a large common offset (~116°) that the ‖p‖²−2p·c+‖c‖² MXU
    # formulation would otherwise cancel catastrophically in f32.
    shift = jnp.mean(points, axis=0, keepdims=True)
    points = points - shift
    centers = centers - shift

    def run_block(pts_block):
        idx, d2min = pl.pallas_call(
            _nearest_kernel,
            grid=(n_kb,),
            in_specs=[
                # Point block: resident across the whole K sweep.
                pl.BlockSpec((B_BLK, d), lambda kb: (0, 0)),
                # Center block: marches along K with the grid.
                pl.BlockSpec((K_BLK, d), lambda kb: (kb, 0)),
                pl.BlockSpec((K_BLK,), lambda kb: (kb,)),
            ],
            out_specs=[
                pl.BlockSpec((B_BLK,), lambda kb: (0,)),
                pl.BlockSpec((B_BLK,), lambda kb: (0,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B_BLK,), jnp.int32),
                jax.ShapeDtypeStruct((B_BLK,), jnp.float32),
            ],
            interpret=True,
        )(pts_block, centers, valid)
        return idx, jnp.sqrt(jnp.maximum(d2min, 0.0))

    if b == B_BLK:
        return run_block(points)
    # Fold larger batches block-by-block (unrolled at trace time — B is
    # static in the AOT artifact).
    idxs, dists = [], []
    for i in range(b // B_BLK):
        idx, dist = run_block(jax.lax.dynamic_slice_in_dim(points, i * B_BLK, B_BLK))
        idxs.append(idx)
        dists.append(dist)
    return jnp.concatenate(idxs), jnp.concatenate(dists)
