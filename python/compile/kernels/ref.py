"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(`python/tests/`) sweeps shapes and dtypes with hypothesis and asserts the
Pallas (interpret-mode) outputs match these to float tolerance.
"""

import jax.numpy as jnp

#: Additive penalty that excludes masked-out centers from the argmin.
#: Large enough to dominate any squared distance between WGS84 coordinates
#: (and any padded-zero center), small enough to stay exact in f32.
INVALID_PENALTY = 1e30


def nearest_ref(points, centers, valid):
    """Nearest valid center per point.

    Args:
      points:  f32[B, D]
      centers: f32[K, D]
      valid:   f32[K] — 1.0 for live centers, 0.0 for padding.

    Returns:
      (idx s32[B], dist f32[B]): argmin index into `centers` and the
      Euclidean distance to it. If no center is valid, idx is the argmin
      of the penalty row (0) and dist is sqrt(INVALID_PENALTY)-ish; the
      rust caller masks that case out before use.
    """
    # Exact (oracle) formulation: direct differences, no cancellation.
    diff = points[:, None, :] - centers[None, :, :]  # [B, K, D]
    d2 = jnp.sum(diff * diff, axis=-1)  # [B, K]
    d2 = d2 + (1.0 - valid)[None, :] * INVALID_PENALTY
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist = jnp.sqrt(jnp.maximum(jnp.min(d2, axis=1), 0.0))
    return idx, dist


def kmeans_step_ref(points, weights, centroids):
    """One weighted Lloyd iteration.

    Args:
      points:    f32[K, D] — micro-cluster centers.
      weights:   f32[K] — micro-cluster sizes (0 for padding).
      centroids: f32[C, D] — current macro centroids.

    Returns:
      (new_centroids f32[C, D], counts f32[C]): weighted means of the
      assigned points; centroids with no mass keep their old position.
    """
    diff = points[:, None, :] - centroids[None, :, :]  # [K, C, D]
    d2 = jnp.sum(diff * diff, axis=-1)  # [K, C]
    assign = jnp.argmin(d2, axis=1)  # [K]
    # Weighted scatter via one-hot matmul (fusable, MXU-friendly).
    oh = (assign[:, None] == jnp.arange(centroids.shape[0])[None, :]).astype(points.dtype)
    w = weights[:, None] * oh  # [K, C]
    counts = jnp.sum(w, axis=0)  # [C]
    sums = w.T @ points  # [C, D]
    safe = jnp.where(counts > 0, counts, 1.0)
    new_centroids = jnp.where(counts[:, None] > 0, sums / safe[:, None], centroids)
    return new_centroids, counts
