"""L2 model + AOT lowering tests: shapes, dtypes, and HLO-text emission."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_tcmm_assign_shapes_and_dtypes():
    b, k = aot.NEAREST_B, aot.NEAREST_K
    pts = jnp.zeros((b, 2), jnp.float32)
    ctr = jnp.ones((k, 2), jnp.float32)
    valid = jnp.ones((k,), jnp.float32)
    idx, dist = model.tcmm_assign(pts, ctr, valid)
    assert idx.shape == (b,) and idx.dtype == jnp.int32
    assert dist.shape == (b,) and dist.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(dist), np.sqrt(2.0), rtol=1e-5)


def test_tcmm_assign_accepts_f64_inputs():
    b, k = aot.NEAREST_B, aot.NEAREST_K
    idx, dist = model.tcmm_assign(
        jnp.zeros((b, 2), jnp.float64),
        jnp.zeros((k, 2), jnp.float64),
        jnp.ones((k,), jnp.float64),
    )
    assert idx.dtype == jnp.int32
    assert dist.dtype == jnp.float32


def test_macro_kmeans_step_shapes():
    k, c = aot.MACRO_K, aot.MACRO_C
    pts = jnp.zeros((k, 2), jnp.float32)
    wts = jnp.zeros((k,), jnp.float32)
    cen = jnp.arange(c * 2, dtype=jnp.float32).reshape(c, 2)
    new_c, counts = model.macro_kmeans_step(pts, wts, cen)
    assert new_c.shape == (c, 2)
    assert counts.shape == (c,)
    # All weights zero: centroids unchanged.
    np.testing.assert_allclose(np.asarray(new_c), np.asarray(cen), atol=1e-6)


def test_lowering_produces_hlo_text():
    text = aot.to_hlo_text(aot.lower_nearest())
    assert "HloModule" in text
    assert "ENTRY" in text
    # Tuple return (rust side unwraps a tuple).
    assert "tuple" in text.lower()

    text2 = aot.to_hlo_text(aot.lower_kmeans())
    assert "HloModule" in text2


def test_aot_main_writes_artifacts(tmp_path):
    import sys
    from unittest import mock

    out = tmp_path / "artifacts"
    with mock.patch.object(sys, "argv", ["aot", "--out", str(out)]):
        aot.main()
    manifest = (out / "manifest.txt").read_text()
    assert "nearest" in manifest and "kmeans" in manifest
    for line in manifest.splitlines():
        if line.startswith("#") or not line.strip():
            continue
        name, file, meta = line.split("\t")
        assert (out / file).is_file(), f"missing artifact {file}"
        assert "=" in meta


def test_compiled_execution_matches_eager():
    """The lowered computation must agree with eager execution — this is
    the exact graph rust loads."""
    b, k = aot.NEAREST_B, aot.NEAREST_K
    rng = np.random.default_rng(1)
    pts = (116.4 + rng.normal(0, 0.01, (b, 2))).astype(np.float32)
    ctr = np.zeros((k, 2), np.float32)
    ctr[:4] = 116.4 + rng.normal(0, 0.01, (4, 2))
    valid = np.zeros(k, np.float32)
    valid[:4] = 1.0

    eager_idx, eager_dist = model.tcmm_assign(
        jnp.array(pts), jnp.array(ctr), jnp.array(valid)
    )
    compiled = jax.jit(model.tcmm_assign).lower(
        jax.ShapeDtypeStruct((b, 2), jnp.float32),
        jax.ShapeDtypeStruct((k, 2), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
    ).compile()
    comp_idx, comp_dist = compiled(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    np.testing.assert_array_equal(np.asarray(eager_idx), np.asarray(comp_idx))
    np.testing.assert_allclose(np.asarray(eager_dist), np.asarray(comp_dist), rtol=1e-6)
