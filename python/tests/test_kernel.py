"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps the padded shapes and data distributions; fixed cases
pin down the edge behaviours (all-invalid masks, ties, padding rows).
"""

import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import kmeans, nearest
from compile.kernels.ref import kmeans_step_ref, nearest_ref

B_BLK = nearest.B_BLK
K_BLK = nearest.K_BLK


def coords(shape):
    """Finite f32 coordinate arrays in the kernels' deployment envelope: a
    large common offset (up to ±200, like GPS longitudes) plus a local
    spread of a few degrees. The kernels mean-center internally, so the
    offset cancels; testing unbounded spreads would only measure the f32
    cancellation floor of the MXU distance expansion, not kernel bugs."""
    return st.integers(-200, 200).flatmap(
        lambda off: hnp.arrays(
            np.float32,
            shape,
            elements=st.floats(
                float(off) - 2.0, float(off) + 2.0, width=32, allow_nan=False
            ),
        )
    )


# --- nearest ---------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    b_mult=st.integers(1, 2),
    k_mult=st.integers(1, 2),
)
def test_nearest_matches_ref(data, b_mult, k_mult):
    b, k = B_BLK * b_mult, K_BLK * k_mult
    pts = data.draw(coords((b, 2)))
    ctr = data.draw(coords((k, 2)))
    n_valid = data.draw(st.integers(1, k))
    valid = np.zeros(k, np.float32)
    valid[:n_valid] = 1.0

    idx, dist = nearest.nearest(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    ref_idx, ref_dist = nearest_ref(jnp.array(pts), jnp.array(ctr), jnp.array(valid))

    # atol bounded by f32 cancellation of the MXU expansion at the test's
    # local spread (±2°): worst case ≈ ulp(|c|²)/(2·dist) ≈ a few 1e-3.
    np.testing.assert_allclose(dist, ref_dist, rtol=1e-4, atol=5e-3)
    # Argmin indices may differ only on (near-)ties: compare by distance.
    d_via_idx = np.linalg.norm(pts - ctr[np.asarray(idx)], axis=1)
    d_via_ref = np.linalg.norm(pts - ctr[np.asarray(ref_idx)], axis=1)
    np.testing.assert_allclose(d_via_idx, d_via_ref, rtol=1e-3, atol=5e-3)
    # Chosen centers must be valid.
    assert valid[np.asarray(idx)].all()


def test_nearest_basic_exact():
    pts = np.zeros((B_BLK, 2), np.float32)
    pts[0] = [9.0, 1.0]
    pts[1] = [0.1, 0.1]
    ctr = np.zeros((K_BLK * 2, 2), np.float32)
    ctr[0] = [0.0, 0.0]
    ctr[1] = [10.0, 0.0]
    # A closer but INVALID center — must be ignored.
    ctr[2] = [9.0, 1.0]
    valid = np.zeros(K_BLK * 2, np.float32)
    valid[:2] = 1.0

    idx, dist = nearest.nearest(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    assert int(idx[0]) == 1
    assert int(idx[1]) == 0
    np.testing.assert_allclose(float(dist[0]), np.sqrt(2.0), rtol=1e-5)
    np.testing.assert_allclose(float(dist[1]), np.sqrt(0.02), rtol=1e-4, atol=1e-6)


def test_nearest_center_in_second_block():
    """The argmin must fold across K blocks (global index offset)."""
    pts = np.full((B_BLK, 2), 50.0, np.float32)
    k = K_BLK * 2
    ctr = np.zeros((k, 2), np.float32)
    target = K_BLK + 7  # lives in the second block
    ctr[target] = [50.0, 50.0]
    valid = np.ones(k, np.float32)

    idx, dist = nearest.nearest(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    assert (np.asarray(idx) == target).all()
    np.testing.assert_allclose(np.asarray(dist), 0.0, atol=1e-3)


def test_nearest_matches_ref_on_clustered_data():
    rng = np.random.default_rng(0)
    hot = rng.uniform([116.0, 39.6], [116.8, 40.2], size=(8, 2)).astype(np.float32)
    pts = (hot[rng.integers(0, 8, B_BLK)] + rng.normal(0, 0.005, (B_BLK, 2))).astype(
        np.float32
    )
    ctr = np.zeros((K_BLK, 2), np.float32)
    ctr[:8] = hot
    valid = np.zeros(K_BLK, np.float32)
    valid[:8] = 1.0
    idx, dist = nearest.nearest(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    ref_idx, ref_dist = nearest_ref(jnp.array(pts), jnp.array(ctr), jnp.array(valid))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_idx))
    np.testing.assert_allclose(dist, ref_dist, rtol=1e-4, atol=1e-5)


# --- kmeans_step -----------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    data=st.data(),
    k_mult=st.integers(1, 2),
    c=st.integers(1, 16),
)
def test_kmeans_step_matches_ref(data, k_mult, c):
    k = kmeans.P_BLK * k_mult
    pts = data.draw(coords((k, 2)))
    cen = data.draw(coords((c, 2)))
    wts = data.draw(
        hnp.arrays(np.float32, (k,), elements=st.floats(0.0, 100.0, width=32))
    )

    new_c, counts = kmeans.kmeans_step(jnp.array(pts), jnp.array(wts), jnp.array(cen))
    ref_c, ref_counts = kmeans_step_ref(jnp.array(pts), jnp.array(wts), jnp.array(cen))

    np.testing.assert_allclose(counts, ref_counts, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(new_c, ref_c, rtol=1e-3, atol=1e-3)


def test_kmeans_step_two_blobs():
    k = kmeans.P_BLK
    pts = np.zeros((k, 2), np.float32)
    wts = np.zeros(k, np.float32)
    pts[0:4] = [[0.0, 0.0], [0.2, 0.0], [10.0, 10.0], [10.2, 10.0]]
    wts[0:4] = [1.0, 1.0, 3.0, 1.0]
    cen = np.array([[1.0, 1.0], [9.0, 9.0]], np.float32)

    new_c, counts = kmeans.kmeans_step(jnp.array(pts), jnp.array(wts), jnp.array(cen))
    # Counts are weighted: padding rows (weight 0) add no mass anywhere.
    np.testing.assert_allclose(np.asarray(counts), [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(new_c)[1], [10.05, 10.0], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_c)[0], [0.1, 0.0], atol=1e-5)


def test_kmeans_step_empty_centroid_keeps_position():
    k = kmeans.P_BLK
    pts = np.zeros((k, 2), np.float32)
    wts = np.zeros(k, np.float32)
    pts[0] = [0.0, 0.0]
    wts[0] = 5.0
    cen = np.array([[0.1, 0.0], [99.0, 99.0]], np.float32)
    new_c, counts = kmeans.kmeans_step(jnp.array(pts), jnp.array(wts), jnp.array(cen))
    assert float(counts[1]) == pytest.approx(0.0)
    np.testing.assert_allclose(np.asarray(new_c)[1], [99.0, 99.0])
    np.testing.assert_allclose(np.asarray(new_c)[0], [0.0, 0.0], atol=1e-6)
