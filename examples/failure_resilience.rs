//! Resilience demo: certain node failures every epoch; watch the
//! supervision service detect and regenerate components while the Liquid
//! baseline waits for node restarts (the Fig. 10 story, live).
//!
//! ```sh
//! cargo run --release --example failure_resilience
//! ```

use reactive_liquid::config::{Architecture, ExperimentConfig, TcmmBackend};
use reactive_liquid::experiment::run_experiment;

fn cfg(arch: Architecture) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.arch = arch;
    cfg.duration_paper_min = 16.0;
    cfg.failure_prob = 0.9; // the paper's harshest setting
    cfg.failure_epoch_paper_min = 4.0;
    cfg.restart_paper_min = 2.0;
    cfg.workload.taxis = 100;
    cfg.workload.points_per_taxi = 100;
    cfg.workload.ingest_rate = 2000;
    cfg.backend = TcmmBackend::Cpu;
    cfg.elastic.max_workers = 8;
    cfg
}

fn main() {
    println!("=== 90% node-failure probability per epoch, both architectures ===\n");

    let liquid = run_experiment(&cfg(Architecture::Liquid { tasks_per_job: 3 }));
    println!("liquid-3 : {}", liquid.summary());

    let reactive = run_experiment(&cfg(Architecture::Reactive));
    println!("reactive : {}", reactive.summary());

    println!("\n--- interpretation ---");
    println!(
        "liquid-3 lost its tasks on every node failure and waited the full \
         restart delay to get them back ({} failures, 0 supervised restarts).",
        liquid.node_failures
    );
    println!(
        "reactive was hit just as often ({} failures) but its supervision \
         service regenerated components {} times on healthy nodes.",
        reactive.node_failures, reactive.supervisor_restarts
    );
    let ratio = reactive.total_processed as f64 / liquid.total_processed.max(1) as f64;
    println!(
        "\nprocessed under failures: reactive {} vs liquid {} ({ratio:.2}x)",
        reactive.total_processed, liquid.total_processed
    );
    assert!(reactive.supervisor_restarts > 0);
    println!("\nfailure_resilience OK");
}
