//! Elasticity demo: a bursty workload drives the elastic worker service —
//! watch task counts follow queue depth up and back down (§3.2.2).
//!
//! ```sh
//! cargo run --release --example elastic_scaling
//! ```

use reactive_liquid::actor::system::ActorSystem;
use reactive_liquid::config::{ElasticConfig, PolicyKind, RouterPolicy};
use reactive_liquid::messaging::{Broker, Producer};
use reactive_liquid::metrics::PipelineMetrics;
use reactive_liquid::processing::job::Job;
use reactive_liquid::processing::reactive::ReactiveJob;
use reactive_liquid::reactive::state::OffsetStore;
use reactive_liquid::reactive::supervision::Supervisor;
use reactive_liquid::util::clock::real_clock;
use reactive_liquid::vml::virtual_topic::VirtualTopic;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let broker = Broker::new();
    broker.create_topic("load", 3);
    let client: reactive_liquid::messaging::SharedBrokerClient = broker.clone();
    let clock = real_clock();
    let metrics = PipelineMetrics::new(clock.clone());
    let system = ActorSystem::new();
    let supervisor = Supervisor::new(clock.clone(), Duration::from_millis(100));
    let offsets = Arc::new(OffsetStore::in_memory());
    let vt = VirtualTopic::new("load", &client, &system, clock.clone(), metrics.clone(), offsets.clone(), (2, 1, 4));

    // Each message takes ~2 ms to "process" — queues form fast.
    let job = Job::from_fn("slow", "load", None, |_env| {
        std::thread::sleep(Duration::from_millis(2));
        vec![]
    });
    let elastic = ElasticConfig {
        min_workers: 1,
        max_workers: 10,
        high_watermark: 32,
        low_watermark: 4,
        check_interval: Duration::from_millis(100),
        cooldown: Duration::from_millis(200),
        policy: PolicyKind::Threshold,
    };
    let rj = ReactiveJob::start(
        &system, &client, job, &vt, None, &supervisor, elastic,
        RouterPolicy::ShortestQueue, 16, 1, clock.clone(), metrics.clone(), offsets,
    );
    supervisor.start();

    let producer = Producer::new(&broker, "load", clock.clone());
    println!("t(s)  phase     tasks  queued  processed");
    let start = std::time::Instant::now();
    let log = |phase: &str, rj: &ReactiveJob| {
        println!(
            "{:>4.1}  {:8}  {:>5}  {:>6}  {:>9}",
            start.elapsed().as_secs_f64(),
            phase,
            rj.pool.task_count(),
            rj.router.total_depth(),
            rj.total_processed(),
        );
    };

    // Phase 1: idle.
    std::thread::sleep(Duration::from_millis(500));
    log("idle", &rj);
    let baseline_tasks = rj.pool.task_count();

    // Phase 2: burst — 4000 messages at once.
    for i in 0..4000u64 {
        producer.send(None, i.to_le_bytes().to_vec());
    }
    let mut peak_tasks = 0;
    for _ in 0..40 {
        std::thread::sleep(Duration::from_millis(250));
        peak_tasks = peak_tasks.max(rj.pool.task_count());
        log("burst", &rj);
        if rj.total_processed() >= 4000 {
            break;
        }
    }

    // Phase 3: drain back down.
    for _ in 0..20 {
        std::thread::sleep(Duration::from_millis(300));
        log("drain", &rj);
        if rj.pool.task_count() <= elastic.min_workers {
            break;
        }
    }

    println!("\nscale history: {:?}", rj.elastic.history().iter().map(|(_, n)| *n).collect::<Vec<_>>());
    println!("baseline {} → peak {} → final {}", baseline_tasks, peak_tasks, rj.pool.task_count());
    assert!(peak_tasks > baseline_tasks, "elastic service scaled out under load");

    supervisor.stop();
    rj.stop();
    vt.stop();
    system.shutdown();
    println!("elastic_scaling OK");
}
