//! End-to-end driver (EXPERIMENTS.md §E2E): the paper's full evaluation
//! workload on the full stack — synthetic T-Drive trajectories through the
//! micro-/macro-clustering pipeline under Reactive Liquid, with the
//! AOT-compiled JAX/Pallas kernel on the hot path, elastic scaling and
//! supervision active, and the headline metrics reported.
//!
//! ```sh
//! make artifacts && cargo run --release --example tcmm_pipeline -- \
//!     --secs 30 --rate 4000 --backend xla
//! ```

use reactive_liquid::config::{Architecture, ExperimentConfig, TcmmBackend};
use reactive_liquid::config::cli::Args;
use reactive_liquid::experiment::run_experiment;

fn main() {
    let mut args = Args::from_env().expect("args");
    let secs: f64 = args.opt_or("secs", 30.0).expect("--secs");
    let rate: u64 = args.opt_or("rate", 4000).expect("--rate");
    let backend = match args.opt_str("backend").as_deref() {
        Some("cpu") => TcmmBackend::Cpu,
        _ => TcmmBackend::Xla,
    };
    let seed: u64 = args.opt_or("seed", 42).expect("--seed");
    args.finish().expect("unknown args");

    let mut cfg = ExperimentConfig::default();
    cfg.arch = Architecture::Reactive;
    cfg.duration_paper_min = secs; // time_scale 1.0: paper-min == seconds
    cfg.workload.taxis = 200;
    cfg.workload.points_per_taxi = 200;
    cfg.workload.ingest_rate = rate;
    cfg.backend = backend;
    cfg.elastic.max_workers = 12;
    cfg.seed = seed;

    println!("=== TCMM pipeline (Reactive Liquid, backend={backend:?}) ===");
    let r = run_experiment(&cfg);

    println!("\n--- headline metrics (paper §4.3) ---");
    println!("total processed   : {}", r.total_processed);
    println!("mean throughput   : {:.0} msg/s", r.mean_throughput());
    println!("completion        : {}", r.completion.summary());
    println!("node failures     : {}", r.node_failures);
    println!("restarts          : {}", r.supervisor_restarts);
    println!("\n--- counters ---");
    for (k, v) in &r.counters {
        println!("{k:32} {v}");
    }
    println!("\n--- cumulative processed (last 5 samples) ---");
    for (s, n) in r.cumulative.iter().rev().take(5).rev() {
        println!("t={s:>4}s  total={n}");
    }
    assert!(r.total_processed > 0, "pipeline processed nothing");
    println!("\ntcmm_pipeline OK");
}
