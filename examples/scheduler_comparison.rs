//! §5 future work, implemented: the completion-time-aware message
//! distribution scheduler vs round-robin vs join-the-shortest-queue.
//! The paper's conclusion says such a scheduler "is crucial to minimize
//! the completion time of the messages" — this example quantifies it.
//!
//! ```sh
//! cargo run --release --example scheduler_comparison
//! ```

use reactive_liquid::config::{Architecture, ExperimentConfig, RouterPolicy, TcmmBackend};
use reactive_liquid::experiment::run_experiment;

fn main() {
    let policies =
        [RouterPolicy::RoundRobin, RouterPolicy::ShortestQueue, RouterPolicy::CompletionTime];

    println!("policy            total     mean-compl  p95-compl   throughput");
    let mut rows = Vec::new();
    for policy in policies {
        let mut cfg = ExperimentConfig::default();
        cfg.arch = Architecture::Reactive;
        cfg.router = policy;
        cfg.duration_paper_min = 15.0;
        cfg.workload.taxis = 100;
        cfg.workload.points_per_taxi = 150;
        cfg.workload.ingest_rate = 2500;
        cfg.backend = TcmmBackend::Cpu;
        cfg.elastic.max_workers = 10;
        // Heterogeneous task speeds (1×–4×): the regime where the
        // distribution scheduler has leverage (see DESIGN.md).
        cfg.task_speed_spread = 3.0;
        let r = run_experiment(&cfg);
        println!(
            "{:16}  {:>8}  {:>9.2}ms  {:>8.2}ms  {:>7.0}/s",
            policy.label(),
            r.total_processed,
            r.completion.mean().as_secs_f64() * 1e3,
            r.completion.quantile(0.95).as_secs_f64() * 1e3,
            r.mean_throughput(),
        );
        rows.push((policy, r));
    }

    let rr_mean = rows[0].1.completion.mean().as_secs_f64();
    let ct_mean = rows[2].1.completion.mean().as_secs_f64();
    println!(
        "\ncompletion-time scheduler vs round-robin: {:.2}x mean completion",
        ct_mean / rr_mean
    );
    println!("scheduler_comparison OK");
}
