//! Quickstart: the five-layer Reactive Liquid stack on a toy word-length
//! pipeline, in ~60 lines of user code.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use reactive_liquid::actor::system::ActorSystem;
use reactive_liquid::config::{ElasticConfig, RouterPolicy};
use reactive_liquid::messaging::{Broker, Message};
use reactive_liquid::metrics::PipelineMetrics;
use reactive_liquid::processing::job::Job;
use reactive_liquid::processing::reactive::ReactiveJob;
use reactive_liquid::reactive::state::OffsetStore;
use reactive_liquid::reactive::supervision::Supervisor;
use reactive_liquid::util::clock::real_clock;
use reactive_liquid::vml::virtual_topic::VirtualTopic;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // 1. Messaging layer: a broker with two 3-partition topics. The
    //    layers above hold it through the BrokerClient seam — swap in a
    //    transport::RemoteBroker and this same pipeline runs against a
    //    broker in another process.
    let broker = Broker::new();
    broker.create_topic("sentences", 3);
    broker.create_topic("lengths", 3);
    let client: reactive_liquid::messaging::SharedBrokerClient = broker.clone();

    // 2. Platform services.
    let clock = real_clock();
    let metrics = PipelineMetrics::new(clock.clone());
    let system = ActorSystem::new();
    let supervisor = Supervisor::new(clock.clone(), Duration::from_millis(100));
    supervisor.start();
    let offsets = Arc::new(OffsetStore::in_memory());

    // 3. Virtual messaging layer: one virtual topic per topic.
    let mk_vt = |name: &str| {
        VirtualTopic::new(name, &client, &system, clock.clone(), metrics.clone(), offsets.clone(), (2, 1, 4))
    };
    let vt_in = mk_vt("sentences");
    let vt_out = mk_vt("lengths");

    // 4. A job: sentence → its word count. Note SIX tasks on a
    //    THREE-partition topic — the thing Liquid cannot do.
    let job = Job::from_fn("wordcount", "sentences", Some("lengths"), |env| {
        let text = env.message.payload_str().unwrap_or("");
        let words = text.split_whitespace().count();
        vec![Message::new(None, words.to_string().into_bytes(), 0)]
    });
    let rj = ReactiveJob::start(
        &system,
        &client,
        job,
        &vt_in,
        Some(&vt_out),
        &supervisor,
        ElasticConfig { min_workers: 2, max_workers: 6, ..Default::default() },
        RouterPolicy::ShortestQueue,
        16,
        6,
        clock.clone(),
        metrics.clone(),
        offsets,
    );

    // 5. Feed it and watch the output topic fill.
    let producer = reactive_liquid::messaging::Producer::new(&broker, "sentences", clock.clone());
    let corpus = [
        "the quick brown fox jumps over the lazy dog",
        "reactive systems stay responsive under load and failure",
        "the virtual messaging layer lifts the partition cap",
    ];
    for i in 0..300 {
        producer.send(None, corpus[i % corpus.len()].as_bytes().to_vec());
    }

    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let out_topic = broker.topic("lengths").unwrap();
    while out_topic.total_messages() < 300 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }

    println!("processed  : {}", rj.total_processed());
    println!("outputs    : {}", out_topic.total_messages());
    println!("tasks used : {} (> 3 partitions!)", rj.pool.task_count());
    println!("completion : {}", metrics.completion.histogram().summary());
    assert_eq!(out_topic.total_messages(), 300);

    supervisor.stop();
    rj.stop();
    vt_in.stop();
    vt_out.stop();
    system.shutdown();
    println!("quickstart OK");
}
